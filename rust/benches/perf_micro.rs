//! Performance microbenchmarks — the §Perf instrumentation of
//! EXPERIMENTS.md: enumerator throughput, set-op kernels, simulator
//! profiling rate, scheduler event rate, and (when artifacts exist) the
//! PJRT batched-kernel path.

use pimminer::bench::Bench;
use pimminer::exec::cpu::{self, CpuFlavor};
use pimminer::exec::setops::{count_intersect, intersect_into, subtract_into, NO_BOUND};
use pimminer::exec::{Enumerator, NullSink};
use pimminer::graph::{gen, sort_by_degree_desc};
use pimminer::pattern::plan::{application, Plan};
use pimminer::pattern::pattern::clique;
use pimminer::pim::stealing::{schedule, Piece};
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::runtime::{artifacts_available, artifacts_dir, Runtime, SetOpRequest, SetOpsKernel};
use pimminer::util::rng::Rng;
use std::collections::VecDeque;

fn main() {
    let bench = Bench::new("perf_micro");

    // --- set-op kernels ---
    let mut rng = Rng::new(1);
    let mk = |rng: &mut Rng, n: usize| {
        let mut v: Vec<u32> = (0..n).map(|_| rng.below(1 << 20) as u32).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    let a = mk(&mut rng, 4096);
    let b = mk(&mut rng, 4096);
    let mut out = Vec::with_capacity(4096);
    let t = bench.measure("intersect_4k", 3, 50, || {
        intersect_into(&a, &b, NO_BOUND, &mut out)
    });
    println!("  → {:.0}M elem/s", (a.len() + b.len()) as f64 / t / 1e6);
    bench.measure("subtract_4k", 3, 50, || subtract_into(&a, &b, NO_BOUND, &mut out));
    bench.measure("count_intersect_4k", 3, 50, || count_intersect(&a, &b, NO_BOUND));

    // --- enumerator ---
    let g = sort_by_degree_desc(&gen::power_law(20_000, 160_000, 800, 3)).graph;
    let plan = Plan::build(&clique(4));
    let mut e = Enumerator::new(&g, &plan);
    let t = bench.measure("enumerate_4cc_20k_serial", 1, 5, || {
        let mut total = 0u64;
        for v in 0..g.num_vertices() as u32 {
            total += e.count_root(v, &mut NullSink);
        }
        total
    });
    println!("  → {:.0} roots/s serial", g.num_vertices() as f64 / t);
    let app = application("4-CC").unwrap();
    let roots: Vec<u32> = (0..g.num_vertices() as u32).collect();
    let t = bench.measure("enumerate_4cc_20k_parallel", 1, 5, || {
        cpu::count_plan(&g, &plan, &roots, CpuFlavor::AutoMineOpt)
    });
    println!("  → {:.0} roots/s parallel", g.num_vertices() as f64 / t);

    // --- simulator (profiling + scheduling, full ladder config) ---
    let cfg = PimConfig::default();
    let count_t = t;
    let t = bench.measure("simulate_4cc_20k_fullstack", 1, 5, || {
        simulate_app(&g, &app, &roots, &SimOptions::all(), &cfg)
    });
    println!(
        "  → simulation overhead {:.2}x over the raw parallel count",
        t / count_t
    );

    // --- stealing scheduler event rate ---
    let mut queues: Vec<VecDeque<Piece>> = vec![VecDeque::new(); cfg.num_units()];
    let mut srng = Rng::new(7);
    for i in 0..50_000usize {
        queues[i % cfg.num_units()].push_back(Piece {
            cycles: srng.range(100, 10_000),
            chunks: srng.range(1, 64),
        });
    }
    let t = bench.measure("scheduler_50k_pieces", 1, 10, || {
        schedule(&cfg, queues.clone(), true)
    });
    println!("  → {:.1}M pieces/s", 50_000.0 / t / 1e6);

    // --- PJRT batched kernel path ---
    if artifacts_available() {
        let rt = Runtime::cpu().unwrap();
        let kernel =
            SetOpsKernel::load(&rt, &artifacts_dir().join("setops.hlo.txt"), 64, 256).unwrap();
        let mut krng = Rng::new(5);
        let reqs: Vec<SetOpRequest> = (0..512)
            .map(|_| SetOpRequest {
                a: mk(&mut krng, 200),
                b: mk(&mut krng, 200),
                th: krng.below(1 << 20) as u32,
            })
            .collect();
        let t = bench.measure("pjrt_setops_512pairs", 1, 5, || kernel.run(&reqs).unwrap());
        println!("  → {:.0} pairs/s through the AOT artifact", 512.0 / t);
    } else {
        println!("pjrt kernel bench skipped (run `make artifacts`)");
    }
}
