//! Performance microbenchmarks — the §Perf instrumentation of
//! EXPERIMENTS.md: set-op kernels (sorted merge vs the hybrid
//! sparse/dense engine), enumerator throughput (merge vs hub bitmaps),
//! simulator profiling rate, scheduler event rate, and (when artifacts
//! exist) the PJRT batched-kernel path.
//!
//! `cargo bench --bench perf_micro -- --json` additionally writes every
//! timing and derived metric to `BENCH_micro.json` at the repo root —
//! the perf trajectory seed `make bench` refreshes and CI archives.

use pimminer::bench::Bench;
use pimminer::exec::cpu::{self, CpuFlavor};
use pimminer::exec::setops::{
    count_intersect, count_intersect_hybrid, intersect_into, intersect_into_hybrid,
    subtract_into, NO_BOUND,
};
use pimminer::exec::{Enumerator, NullSink};
use pimminer::graph::{gen, sort_by_degree_desc, HubBitmaps};
use pimminer::pattern::plan::{application, Plan};
use pimminer::pattern::pattern::clique;
use pimminer::pim::stealing::{schedule, Piece};
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::runtime::{artifacts_available, artifacts_dir, Runtime, SetOpRequest, SetOpsKernel};
use pimminer::util::rng::Rng;
use std::collections::VecDeque;

/// Exactly `n` distinct sorted ids from `[0, 1<<20)`. (The previous
/// sort+dedup version silently shrank below the advertised size, so the
/// `*_4k` labels and the elem/s math overstated the work.)
fn mk(rng: &mut Rng, n: usize) -> Vec<u32> {
    let mut seen = std::collections::HashSet::with_capacity(n * 2);
    let mut v: Vec<u32> = Vec::with_capacity(n);
    while v.len() < n {
        let x = rng.below(1 << 20) as u32;
        if seen.insert(x) {
            v.push(x);
        }
    }
    v.sort_unstable();
    v
}

fn main() {
    let bench = Bench::new("perf_micro");

    // --- set-op kernels (random 4k lists) ---
    let mut rng = Rng::new(1);
    let a = mk(&mut rng, 4096);
    let b = mk(&mut rng, 4096);
    assert_eq!(a.len() + b.len(), 8192, "mk must deliver exact lengths");
    let mut out = Vec::with_capacity(4096);
    let t = bench.measure("intersect_4k", 3, 50, || {
        intersect_into(&a, &b, NO_BOUND, &mut out)
    });
    bench.metric(
        "intersect_4k_melems_per_s",
        (a.len() + b.len()) as f64 / t / 1e6,
        "M elem/s",
    );
    bench.measure("subtract_4k", 3, 50, || subtract_into(&a, &b, NO_BOUND, &mut out));
    bench.measure("count_intersect_4k", 3, 50, || count_intersect(&a, &b, NO_BOUND));

    // --- hybrid kernels on real hub adjacency (DESIGN.md §10) ---
    let g = sort_by_degree_desc(&gen::power_law(20_000, 160_000, 800, 3)).graph;
    let hubs = HubBitmaps::build(&g, None);
    let h = hubs.prefix();
    bench.metric("hub_prefix", h as f64, "vertices");
    bench.metric("hub_bitmap_bytes", hubs.total_bytes() as f64, "bytes");
    let (na, nb) = (g.neighbors(0), g.neighbors(1));
    let t_merge = bench.measure("hub_pair_intersect_merge", 3, 200, || {
        intersect_into(na, nb, h, &mut out)
    });
    let t_dense = bench.measure("hub_pair_intersect_dense", 3, 200, || {
        intersect_into_hybrid(Some(&hubs), na, Some(0), nb, Some(1), h, &mut out)
    });
    bench.metric("hub_pair_dense_speedup", t_merge / t_dense, "x");
    let t_count_merge = bench.measure("hub_pair_count_merge", 3, 200, || {
        count_intersect(na, nb, h)
    });
    let t_count = bench.measure("hub_pair_count_dense", 3, 200, || {
        count_intersect_hybrid(Some(&hubs), na, Some(0), nb, Some(1), h)
    });
    bench.metric("hub_pair_count_speedup", t_count_merge / t_count, "x");
    // sparse-dense probe: a cold mid-degree list against a hub row
    let probe_v = (h + (g.num_vertices() as u32 - h) / 2).min(g.num_vertices() as u32 - 1);
    let np = g.neighbors(probe_v);
    let t_pm = bench.measure("probe_pair_intersect_merge", 3, 200, || {
        intersect_into(np, na, NO_BOUND, &mut out)
    });
    let t_pp = bench.measure("probe_pair_intersect_probe", 3, 200, || {
        intersect_into_hybrid(Some(&hubs), np, Some(probe_v), na, Some(0), NO_BOUND, &mut out)
    });
    bench.metric("probe_pair_speedup", t_pm / t_pp, "x");

    // --- enumerator (4-CC on the 20k power-law graph) ---
    let plan = Plan::build(&clique(4));
    let nv = g.num_vertices();
    let mut e = Enumerator::new(&g, &plan);
    let t_serial = bench.measure("enumerate_4cc_20k_serial", 1, 5, || {
        let mut total = 0u64;
        for v in 0..nv as u32 {
            total += e.count_root(v, &mut NullSink);
        }
        total
    });
    bench.metric("enumerate_4cc_20k_serial_roots_per_s", nv as f64 / t_serial, "roots/s");
    let mut eh = Enumerator::with_hubs(&g, &plan, Some(&hubs));
    let t_serial_h = bench.measure("enumerate_4cc_20k_serial_hybrid", 1, 5, || {
        let mut total = 0u64;
        for v in 0..nv as u32 {
            total += eh.count_root(v, &mut NullSink);
        }
        total
    });
    bench.metric(
        "enumerate_4cc_20k_serial_hybrid_roots_per_s",
        nv as f64 / t_serial_h,
        "roots/s",
    );
    bench.metric("enumerate_4cc_20k_hybrid_speedup", t_serial / t_serial_h, "x");

    let app = application("4-CC").unwrap();
    let roots: Vec<u32> = (0..nv as u32).collect();
    let t_par = bench.measure("enumerate_4cc_20k_parallel", 1, 5, || {
        cpu::count_plan(&g, &plan, &roots, CpuFlavor::AutoMineOpt)
    });
    bench.metric("enumerate_4cc_20k_parallel_roots_per_s", nv as f64 / t_par, "roots/s");
    let t_par_h = bench.measure("enumerate_4cc_20k_parallel_hybrid", 1, 5, || {
        cpu::count_plan_hybrid(&g, &plan, &roots, CpuFlavor::AutoMineOpt, Some(&hubs))
    });
    bench.metric(
        "enumerate_4cc_20k_parallel_hybrid_roots_per_s",
        nv as f64 / t_par_h,
        "roots/s",
    );
    bench.metric(
        "enumerate_4cc_20k_parallel_hybrid_speedup",
        t_par / t_par_h,
        "x",
    );

    // --- simulator (profiling + scheduling, full ladder config) ---
    let cfg = PimConfig::default();
    let t_sim = bench.measure("simulate_4cc_20k_fullstack", 1, 5, || {
        simulate_app(&g, &app, &roots, &SimOptions::all(), &cfg)
    });
    bench.metric("simulate_4cc_20k_roots_per_s", nv as f64 / t_sim, "roots/s");
    bench.metric("simulate_overhead_vs_parallel_count", t_sim / t_par, "x");
    let hub_opts = SimOptions {
        hub_bitmaps: true,
        ..SimOptions::all()
    };
    let t_sim_h = bench.measure("simulate_4cc_20k_fullstack_hub_bitmaps", 1, 5, || {
        simulate_app(&g, &app, &roots, &hub_opts, &cfg)
    });
    bench.metric("simulate_4cc_20k_hub_roots_per_s", nv as f64 / t_sim_h, "roots/s");

    // --- stealing scheduler event rate ---
    let mut queues: Vec<VecDeque<Piece>> = vec![VecDeque::new(); cfg.num_units()];
    let mut srng = Rng::new(7);
    for i in 0..50_000usize {
        queues[i % cfg.num_units()].push_back(Piece {
            cycles: srng.range(100, 10_000),
            chunks: srng.range(1, 64),
        });
    }
    let t = bench.measure("scheduler_50k_pieces", 1, 10, || {
        schedule(&cfg, queues.clone(), true)
    });
    bench.metric("scheduler_mpieces_per_s", 50_000.0 / t / 1e6, "M pieces/s");

    // --- PJRT batched kernel path ---
    if artifacts_available() {
        let rt = Runtime::cpu().unwrap();
        let kernel =
            SetOpsKernel::load(&rt, &artifacts_dir().join("setops.hlo.txt"), 64, 256).unwrap();
        let mut krng = Rng::new(5);
        let reqs: Vec<SetOpRequest> = (0..512)
            .map(|_| SetOpRequest {
                a: mk(&mut krng, 200),
                b: mk(&mut krng, 200),
                th: krng.below(1 << 20) as u32,
            })
            .collect();
        let t = bench.measure("pjrt_setops_512pairs", 1, 5, || kernel.run(&reqs).unwrap());
        bench.metric("pjrt_pairs_per_s", 512.0 / t, "pairs/s");
    } else {
        println!("pjrt kernel bench skipped (run `make artifacts`)");
    }

    if Bench::json_requested() {
        bench.write_json("BENCH_micro.json").expect("write BENCH_micro.json");
    }
}
