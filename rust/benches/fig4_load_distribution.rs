//! Fig. 4 reproduction: per-core load distribution on baseline PIM
//! (4-CC). The paper shows MI/YT/PA/LJ with pronounced skew; the bench
//! renders the sorted per-core busy-time profile as ASCII bars plus the
//! Exe/Avg and CV summary statistics.

use pimminer::bench::{workloads, Bench};
use pimminer::exec::cpu;
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::report::{load_bars, Table};
use pimminer::util::stats;

fn main() {
    let bench = Bench::new("fig4_load_distribution");
    let app = application("4-CC").unwrap();
    let cfg = PimConfig::default();
    let mut summary = Table::new(
        "Fig. 4 summary — load imbalance on baseline PIM (4-CC)",
        &["Graph", "Exe/Avg", "CV", "max busy", "min busy"],
    );
    for inst in workloads::graphs(&["MI", "YT", "PA", "LJ"]) {
        let g = &inst.graph;
        let roots = cpu::sampled_roots(g.num_vertices(), inst.sample_ratio);
        let r = bench.fixture(inst.spec.abbrev, || {
            simulate_app(g, &app, &roots, &SimOptions::BASELINE, &cfg)
        });
        let busy: Vec<f64> = r.unit_busy.iter().map(|&b| b as f64).collect();
        print!(
            "{}",
            load_bars(
                &format!("Fig. 4 — {} per-core load (sorted)", inst.spec.abbrev),
                &r.unit_busy,
                16,
            )
        );
        summary.row(vec![
            inst.spec.abbrev.to_string(),
            format!("{:.2}", r.exe_over_avg()),
            format!("{:.2}", stats::cv(&busy)),
            format!("{:.2e}", busy.iter().cloned().fold(0.0, f64::max)),
            format!("{:.2e}", busy.iter().cloned().fold(f64::MAX, f64::min)),
        ]);
    }
    summary.print();
}
