//! Table 1 reproduction: 96-thread CPU vs 128-core baseline PIM, 4-CC.
//!
//! CPU times are measured on this host (AM(OPT) executor, all host
//! threads); PIM times come from the simulator at Table 4 parameters with
//! no PIMMiner optimizations (the paper's baseline characterization).
//! Shapes, not absolute seconds, are the target (DESIGN.md §2): the small
//! graphs favor PIM (thread-launch overhead dominates the CPU), while the
//! skewed YT/LJ-class graphs erode the PIM advantage via load imbalance.

use pimminer::baselines::published;
use pimminer::bench::{workloads, Bench};
use pimminer::exec::cpu::{self, CpuFlavor};
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::report::{self, Table};

fn main() {
    let bench = Bench::new("table1_cpu_vs_pim");
    let app = application("4-CC").unwrap();
    let cfg = PimConfig::default();
    let mut table = Table::new(
        "Table 1 — CPU vs baseline PIM (4-CC)",
        &[
            "Graph", "CPU(s)", "PIM(s)", "Speedup",
            "paper CPU", "paper PIM", "paper Spd",
        ],
    );
    for inst in workloads::graphs(&["CI", "PP", "AS", "MI", "YT", "PA", "LJ"]) {
        let g = &inst.graph;
        let roots = cpu::sampled_roots(g.num_vertices(), inst.sample_ratio);
        let (cpu_s, pim_s, count_cpu, count_pim) = bench.fixture(inst.spec.abbrev, || {
            // Table 1's CPU column models the paper's 96-thread baseline,
            // which has no plan fusion — keep the per-plan path (for the
            // single-plan 4-CC app the two are identical anyway).
            let c = cpu::run_application_with(
                g,
                &app,
                &roots,
                CpuFlavor::AutoMineOpt,
                None,
                false,
                None,
                None,
            );
            let p = simulate_app(g, &app, &roots, &SimOptions::BASELINE, &cfg);
            (c.seconds, p.seconds, c.count, p.count)
        });
        assert_eq!(count_cpu, count_pim, "{}", inst.spec.abbrev);
        let idx = published::GRAPHS
            .iter()
            .position(|&a| a == inst.spec.abbrev)
            .unwrap();
        let (pc, pp) = published::TABLE1_CPU_VS_PIM[idx];
        table.row(vec![
            inst.spec.abbrev.to_string(),
            report::s(cpu_s),
            report::s(pim_s),
            report::x(cpu_s / pim_s),
            report::s(pc),
            report::s(pp),
            report::x(pc / pp),
        ]);
    }
    table.print();
    println!(
        "note: our host CPU and the instruction-level detail of the PIM cores\n\
         differ from the paper's testbed; compare the cross-graph *ordering* of\n\
         the speedup column, not its magnitude (see EXPERIMENTS.md)."
    );
}
