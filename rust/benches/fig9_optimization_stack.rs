//! Fig. 9 reproduction: the cumulative optimization stack
//! (base → +Filter → +Remap → +Duplication → +Stealing) per application ×
//! graph, reporting total execution time (bar top), average per-core time
//! (solid line), and the §6.1.1 summary: per-optimization average and
//! maximum incremental speedups across all cells.
//!
//! Default: 3 apps × 4 graphs; `PIMMINER_FULL=1` runs all 6 × 7 at the
//! published sizes with the paper's sampling.

use pimminer::bench::{workloads, Bench};
use pimminer::exec::cpu;
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::report::{self, Table};
use pimminer::util::stats;

fn main() {
    let bench = Bench::new("fig9_optimization_stack");
    let cfg = PimConfig::default();
    let full = pimminer::datasets::full_scale();
    let apps: Vec<&str> = if full {
        vec!["3-CC", "4-CC", "5-CC", "3-MC", "4-DI", "4-CL"]
    } else {
        vec!["3-CC", "4-CC", "4-CL"]
    };
    let graphs = workloads::graphs(&["CI", "AS", "MI", "YT"]);

    // incremental speedups per ladder step, across all (app, graph) cells
    let mut increments: [Vec<f64>; 4] = Default::default();
    let step_names = ["Filter", "Remap", "Duplication", "Stealing"];

    for inst in &graphs {
        let g = &inst.graph;
        let mut table = Table::new(
            &format!("Fig. 9 — {} (|V|={}, |E|={})", inst.spec.abbrev, g.num_vertices(), g.num_edges()),
            &["App", "Base", "+Filter", "+Remap", "+Dup", "+Steal", "Total spd", "Avg/Total"],
        );
        for app_name in &apps {
            let app = application(app_name).unwrap();
            let sample = workloads::sample_for(app_name, inst.sample_ratio);
            let roots = cpu::sampled_roots(g.num_vertices(), sample);
            let results: Vec<_> = bench.fixture(&format!("{}-{}", app_name, inst.spec.abbrev), || {
                SimOptions::ladder()
                    .into_iter()
                    .map(|(_, opts)| simulate_app(g, &app, &roots, &opts, &cfg))
                    .collect::<Vec<_>>()
            });
            for (i, name) in step_names.iter().enumerate() {
                let s = results[i].seconds / results[i + 1].seconds;
                increments[i].push(s);
                let _ = name;
            }
            let last = results.last().unwrap();
            table.row(vec![
                app_name.to_string(),
                report::s(results[0].seconds),
                report::s(results[1].seconds),
                report::s(results[2].seconds),
                report::s(results[3].seconds),
                report::s(results[4].seconds),
                report::x(results[0].seconds / last.seconds),
                format!("{:.2}", last.avg_unit_seconds / last.seconds),
            ]);
        }
        table.print();
    }

    // §6.1.1 summary numbers (paper: filter 2.01x avg/17.57x max, remap
    // 1.38x/2.74x, duplication 1.84x/3.05x, stealing 3.01x/26.87x;
    // overall 12.74x avg / 113.76x max).
    let mut summary = Table::new(
        "§6.1.1 per-optimization incremental speedup",
        &["Step", "avg", "max", "paper avg", "paper max"],
    );
    let paper = [(2.01, 17.57), (1.38, 2.74), (1.84, 3.05), (3.01, 26.87)];
    let mut overall_avg = 1.0;
    for (i, name) in step_names.iter().enumerate() {
        let avg = stats::mean(&increments[i]);
        let max = increments[i].iter().cloned().fold(0.0, f64::max);
        overall_avg *= avg;
        summary.row(vec![
            name.to_string(),
            report::x(avg),
            report::x(max),
            report::x(paper[i].0),
            report::x(paper[i].1),
        ]);
    }
    summary.print();
    println!("overall stacked average ≈ {} (paper: 12.74x avg)", report::x(overall_avg));
}
