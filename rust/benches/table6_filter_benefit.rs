//! Table 6 reproduction: the in-bank access filter's traffic reduction
//! and speedup on 4-CC — TM (unfiltered fetch bytes), FM (post-filter
//! bytes), the reduction ratio, and the end-to-end speedup of enabling
//! the filter on baseline PIM.

use pimminer::baselines::published;
use pimminer::bench::{workloads, Bench};
use pimminer::exec::cpu;
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::report::{self, Table};

fn main() {
    let bench = Bench::new("table6_filter_benefit");
    let app = application("4-CC").unwrap();
    let cfg = PimConfig::default();
    let mut table = Table::new(
        "Table 6 — filter benefit (4-CC)",
        &[
            "Graph", "TM", "FM", "Ratio", "Speedup",
            "paper Ratio", "paper Spd",
        ],
    );
    for inst in workloads::graphs(&["CI", "PP", "AS", "MI", "YT", "PA", "LJ"]) {
        let g = &inst.graph;
        let roots = cpu::sampled_roots(g.num_vertices(), inst.sample_ratio);
        let (base, filt) = bench.fixture(inst.spec.abbrev, || {
            let base = simulate_app(g, &app, &roots, &SimOptions::BASELINE, &cfg);
            let filt = simulate_app(
                g,
                &app,
                &roots,
                &SimOptions { filter: true, ..SimOptions::BASELINE },
                &cfg,
            );
            (base, filt)
        });
        // TM = traffic with no filter; FM = traffic with the filter on.
        // (Cache miss patterns differ slightly between the runs, so TM is
        // taken from the unfiltered run — the paper's methodology.)
        let tm = base.fm_bytes;
        let fm = filt.fm_bytes;
        let reduction = 1.0 - fm as f64 / tm as f64;
        let idx = published::GRAPHS
            .iter()
            .position(|&a| a == inst.spec.abbrev)
            .unwrap();
        let (_tm, _fm, pr, ps) = published::TABLE6_FILTER[idx];
        table.row(vec![
            inst.spec.abbrev.to_string(),
            report::bytes(tm),
            report::bytes(fm),
            format!("{:.0}%", reduction * 100.0),
            report::x(base.seconds / filt.seconds),
            format!("{:.0}%", pr * 100.0),
            report::x(ps),
        ]);
    }
    table.print();
    println!("(TM/FM are sampled-run traffic at bench scale; compare the Ratio/Speedup shapes.)");
}
