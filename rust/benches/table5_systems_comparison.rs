//! Table 5 reproduction: GPMI systems comparison. Software baselines
//! (GraphPi-like, AM(ORG), AM(OPT)) are measured live on this host;
//! DIMMining/NDMiner and the paper's own PIMMiner column come from the
//! published constants (the paper also compares against reported numbers,
//! §5); our PIMMiner is the full-stack simulation.
//!
//! Default: 3 apps × 4 graphs, AM(ORG) only on the two smallest graphs
//! (its per-root allocation pathology makes it very slow by design);
//! `PIMMINER_FULL=1` runs everything.

use pimminer::baselines::published::{self, column};
use pimminer::bench::{workloads, Bench};
use pimminer::exec::cpu::{self, CpuFlavor};
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::report::{self, Table};
use pimminer::util::stats;

fn main() {
    let bench = Bench::new("table5_systems_comparison");
    let cfg = PimConfig::default();
    let full = pimminer::datasets::full_scale();
    let apps: Vec<&str> = if full {
        vec!["3-CC", "4-CC", "5-CC", "3-MC", "4-DI", "4-CL"]
    } else {
        vec!["3-CC", "4-CC", "4-DI"]
    };
    let graphs = workloads::graphs(&["CI", "PP", "AS", "MI"]);

    let mut ours_speedups: Vec<f64> = Vec::new(); // vs AM(OPT), measured
    for app_name in &apps {
        let app = application(app_name).unwrap();
        let mut table = Table::new(
            &format!("Table 5 — {app_name} (seconds)"),
            &[
                "Graph", "GraphPi", "AM(ORG)", "AM(OPT)", "PIMMiner(sim)",
                "paper DIM&ND", "paper PIMMiner",
            ],
        );
        for inst in &graphs {
            let g = &inst.graph;
            let sample = workloads::sample_for(app_name, inst.sample_ratio);
            let roots = cpu::sampled_roots(g.num_vertices(), sample);
            let run_org = full || g.num_vertices() <= 20_000;
            let label = format!("{}-{}", app_name, inst.spec.abbrev);
            // The CPU columns model *third-party* systems (GraphPi, the two
            // AutoMine variants), which run one traversal per pattern —
            // keep them on the per-plan path so Table 5's shape is not
            // skewed by our plan fusion (DESIGN.md §11); the PIM column
            // stays per-plan to match.
            let sep = |flavor| {
                cpu::run_application_with(g, &app, &roots, flavor, None, false, None, None)
            };
            let (gp, org, opt, pim) = bench.fixture(&label, || {
                let gp = sep(CpuFlavor::GraphPiLike);
                let org = if run_org {
                    Some(sep(CpuFlavor::AutoMineOrg))
                } else {
                    None
                };
                let opt = sep(CpuFlavor::AutoMineOpt);
                let pim = simulate_app(g, &app, &roots, &SimOptions::all(), &cfg);
                (gp, org, opt, pim)
            });
            assert_eq!(gp.count, opt.count);
            assert_eq!(gp.count, pim.count);
            if let Some(o) = &org {
                assert_eq!(o.count, gp.count);
            }
            ours_speedups.push(opt.seconds / pim.seconds);
            table.row(vec![
                inst.spec.abbrev.to_string(),
                report::s(gp.seconds),
                org.map(|o| report::s(o.seconds)).unwrap_or_else(|| "-".into()),
                report::s(opt.seconds),
                report::s(pim.seconds),
                published::table5(app_name, inst.spec.abbrev, column::DIM_ND)
                    .map(report::s)
                    .unwrap_or_else(|| "-".into()),
                report::s(
                    published::table5(app_name, inst.spec.abbrev, column::PIMMINER).unwrap(),
                ),
            ]);
        }
        table.print();
    }
    println!(
        "measured PIMMiner speedup over AM(OPT): mean {} / max {} (paper: 132.19x avg, 1312x max —\n\
         our CPU column is measured on this host, not a 96-thread Xeon; compare who wins per cell)",
        report::x(stats::mean(&ours_speedups)),
        report::x(ours_speedups.iter().cloned().fold(0.0, f64::max)),
    );
}
