//! Recovery overhead vs fault rate (DESIGN.md §15): the 3-CC ladder on
//! the fixed-seed power-law bench graph under a sweep of fault plans —
//! benign, fail-stop, transient-link at increasing probability, and
//! combined. Counts are asserted bit-identical to the fault-free run
//! for every recoverable plan (the bench-side echo of
//! `tests/prop_faults.rs`), the benign plan must cost exactly zero
//! extra cycles, and the per-plan recovery telemetry (injections,
//! retries, recovery steals, backoff cycles) is reported. `-- --json`
//! writes `BENCH_faults.json` (`make bench` refreshes it, CI uploads
//! it as an artifact).

use pimminer::bench::Bench;
use pimminer::graph::{gen, sort_by_degree_desc};
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app_checked, FaultError, FaultSpec, PimConfig, SimOptions};
use pimminer::report::{self, Table};

fn main() {
    let bench = Bench::new("faults");
    let (n, m, dmax) = if bench.quick() {
        (2_000, 12_000, 200)
    } else {
        (8_000, 64_000, 300)
    };
    let g = sort_by_degree_desc(&gen::power_law(n, m, dmax, 42)).graph;
    let roots: Vec<u32> = (0..g.num_vertices() as u32).collect();
    let cfg = PimConfig::default();
    let app = application("3-CC").unwrap();
    let opts = SimOptions::all();
    bench.config("app", "3-CC");
    bench.config("units", &cfg.num_units().to_string());

    let clean = simulate_app_checked(&g, &app, &roots, &opts, &cfg).unwrap();
    bench.metric("clean total_cycles", clean.total_cycles as f64, "cycles");

    let mut table = Table::new(
        &format!(
            "fault recovery overhead — 3-CC, |V|={} |E|={} (seed 42, {} units)",
            g.num_vertices(),
            g.num_edges(),
            cfg.num_units()
        ),
        &[
            "Fault plan",
            "Cycles",
            "Overhead",
            "Injected",
            "Retries",
            "RecSteals",
            "Backoff",
        ],
    );
    table.row(vec![
        "fault-free".to_string(),
        clean.total_cycles.to_string(),
        report::x(1.0),
        "0".to_string(),
        "0".to_string(),
        "0".to_string(),
        "0".to_string(),
    ]);

    let sweep: [(&str, FaultSpec); 5] = [
        (
            "benign (seed only)",
            FaultSpec {
                seed: 7,
                fail_stop: None,
                transient: 0.0,
            },
        ),
        (
            "fail-stop u17@1k",
            FaultSpec {
                seed: 7,
                fail_stop: Some((17, 1_000)),
                transient: 0.0,
            },
        ),
        (
            "transient p=0.05",
            FaultSpec {
                seed: 7,
                fail_stop: None,
                transient: 0.05,
            },
        ),
        (
            "transient p=0.20",
            FaultSpec {
                seed: 7,
                fail_stop: None,
                transient: 0.2,
            },
        ),
        (
            "fail-stop + p=0.20",
            FaultSpec {
                seed: 7,
                fail_stop: Some((17, 1_000)),
                transient: 0.2,
            },
        ),
    ];
    for (name, spec) in sweep {
        let fopts = SimOptions {
            faults: Some(spec),
            ..opts
        };
        let r = match simulate_app_checked(&g, &app, &roots, &fopts, &cfg) {
            Ok(r) => r,
            Err(e @ FaultError::LinkFailure { .. }) => {
                // A hostile-enough transient stream can legitimately kill
                // a link; record the outcome instead of failing the bench.
                bench.metric(&format!("{name} link_failure"), 1.0, "bool");
                table.row(vec![
                    name.to_string(),
                    format!("({e})"),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                ]);
                continue;
            }
            Err(e) => panic!("{name}: unexpected fault error: {e}"),
        };
        assert_eq!(r.count, clean.count, "{name}: counts survive recovery");
        // Determinism: the same spec replays to the same schedule.
        let replay = simulate_app_checked(&g, &app, &roots, &fopts, &cfg).unwrap();
        assert_eq!(
            format!("{replay:?}"),
            format!("{r:?}"),
            "{name}: fault schedule must be deterministic under its seed"
        );
        let overhead = r.total_cycles as f64 / clean.total_cycles as f64;
        bench.metric(&format!("{name} overhead"), overhead, "x");
        bench.metric(&format!("{name} retries"), r.retries as f64, "retries");
        table.row(vec![
            name.to_string(),
            r.total_cycles.to_string(),
            report::x(overhead),
            r.faults_injected.to_string(),
            r.retries.to_string(),
            r.recovery_steals.to_string(),
            r.backoff_cycles.to_string(),
        ]);
        // The benign plan must ride the fault-free fast path exactly;
        // non-benign overheads are reported, not gated — a perturbed
        // schedule is not provably slower than the heuristic baseline.
        if spec.is_benign() {
            assert_eq!(
                r.total_cycles, clean.total_cycles,
                "benign plan must ride the fault-free fast path"
            );
            assert_eq!(r.faults_injected, 0);
        }
    }

    // Wall-clock: the fault plumbing's host-side cost on the heaviest
    // recoverable plan of the sweep, reported (not gated — the hard
    // ≤1.05x zero-fault gate lives in the `parallel` bench).
    let iters = if bench.quick() { 1 } else { 3 };
    let t_clean = bench.measure("sim/3-CC/fault-free", 1, iters, || {
        simulate_app_checked(&g, &app, &roots, &opts, &cfg).unwrap()
    });
    let heavy = SimOptions {
        faults: Some(sweep[4].1),
        ..opts
    };
    let t_heavy = bench.measure("sim/3-CC/fail+transient", 1, iters, || {
        simulate_app_checked(&g, &app, &roots, &heavy, &cfg)
    });
    bench.metric("heavy_plan_wall_ratio", t_heavy / t_clean, "x");

    table.print();
    if Bench::json_requested() {
        bench.write_json("BENCH_faults.json").unwrap();
    }
}
