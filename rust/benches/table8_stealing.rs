//! Table 8 reproduction: the workload-stealing scheduler's effect on the
//! Exe/Avg load-imbalance ratio and execution time (4-CC), on top of
//! filter + remap + duplication.

use pimminer::baselines::published;
use pimminer::bench::{workloads, Bench};
use pimminer::exec::cpu;
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::report::{self, Table};

fn main() {
    let bench = Bench::new("table8_stealing");
    let app = application("4-CC").unwrap();
    let cfg = PimConfig::default();
    let mut table = Table::new(
        "Table 8 — workload stealing (4-CC)",
        &[
            "Graph", "Exe/Avg (no steal)", "Exe/Avg (steal)", "Steals", "Speedup",
            "paper no-steal", "paper steal", "paper Spd",
        ],
    );
    for inst in workloads::graphs(&["CI", "PP", "AS", "MI", "YT", "PA", "LJ"]) {
        let g = &inst.graph;
        let roots = cpu::sampled_roots(g.num_vertices(), inst.sample_ratio);
        let no_steal = SimOptions {
            filter: true,
            remap: true,
            duplication: true,
            ..SimOptions::BASELINE
        };
        let steal = SimOptions { stealing: true, ..no_steal };
        let (a, b) = bench.fixture(inst.spec.abbrev, || {
            (
                simulate_app(g, &app, &roots, &no_steal, &cfg),
                simulate_app(g, &app, &roots, &steal, &cfg),
            )
        });
        assert_eq!(a.count, b.count);
        let idx = published::GRAPHS
            .iter()
            .position(|&x| x == inst.spec.abbrev)
            .unwrap();
        let (pn, ps, pspd) = published::TABLE8_STEALING[idx];
        table.row(vec![
            inst.spec.abbrev.to_string(),
            format!("{:.3}", a.exe_over_avg()),
            format!("{:.3}", b.exe_over_avg()),
            b.steals.to_string(),
            report::x(a.seconds / b.seconds),
            format!("{pn:.2}"),
            format!("{ps:.3}"),
            report::x(pspd),
        ]);
    }
    table.print();
}
