//! Table 2 reproduction: PIM memory-access class distribution under the
//! default (host-optimized) address mapping, 4-CC. The paper's headline
//! observation — >95% of accesses are inter-channel remote — must emerge
//! from the interleaved mapping for every graph.

use pimminer::baselines::published;
use pimminer::bench::{workloads, Bench};
use pimminer::exec::cpu;
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::report::{pct, Table};

fn main() {
    let bench = Bench::new("table2_access_distribution");
    let app = application("4-CC").unwrap();
    let cfg = PimConfig::default();
    let mut table = Table::new(
        "Table 2 — access distribution, default mapping (4-CC)",
        &[
            "Graph", "Near", "Intra", "Inter",
            "paper Near", "paper Intra", "paper Inter",
        ],
    );
    for inst in workloads::graphs(&["CI", "PP", "AS", "MI", "YT", "PA", "LJ"]) {
        let g = &inst.graph;
        let roots = cpu::sampled_roots(g.num_vertices(), inst.sample_ratio);
        let r = bench.fixture(inst.spec.abbrev, || {
            simulate_app(g, &app, &roots, &SimOptions::BASELINE, &cfg)
        });
        assert!(
            r.access.inter_frac() > 0.9,
            "{}: inter fraction {} below the paper's >95% regime",
            inst.spec.abbrev,
            r.access.inter_frac()
        );
        let idx = published::GRAPHS
            .iter()
            .position(|&a| a == inst.spec.abbrev)
            .unwrap();
        let (pn, pi, pr) = published::TABLE2_ACCESS_DIST[idx];
        table.row(vec![
            inst.spec.abbrev.to_string(),
            pct(r.access.near_frac()),
            pct(r.access.intra_frac()),
            pct(r.access.inter_frac()),
            format!("{pn:.2}%"),
            format!("{pi:.2}%"),
            format!("{pr:.2}%"),
        ]);
    }
    table.print();
}
