//! Host CPU scaling for the Chase–Lev work-stealing runtime
//! (DESIGN.md §12): fused enumeration of the CC clique ladder and 4-MC
//! at 1/2/4/8 pinned workers on the fixed-seed power-law bench graph.
//! Counts are asserted bit-identical at every worker count (the cheap
//! end of `tests/prop_parallel.rs`' matrix), steal telemetry is
//! reported per point, and — on hosts that actually have ≥4 cores, in
//! full mode — the 4-thread clique-ladder run must clear 2× over
//! serial. `-- --json` writes `BENCH_parallel.json` (`make bench`
//! refreshes it, CI uploads it as an artifact).

use pimminer::bench::Bench;
use pimminer::exec::cpu::{self, CpuFlavor};
use pimminer::graph::{gen, sort_by_degree_desc};
use pimminer::obs::metrics;
use pimminer::pattern::fuse::PlanTrie;
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app_checked, FaultSpec, PimConfig, SimOptions};
use pimminer::report::{self, Table};
use pimminer::util::ws;
use std::sync::atomic::{AtomicU64, Ordering};

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let bench = Bench::new("parallel");
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    bench.metric("host_cores", cores as f64, "cores");
    bench.config("fused", "true");
    bench.config("partitioner", "n/a");
    bench.config("hub_bitmaps", "false");
    // Fixed-seed power-law graph: the hub skew is what makes static
    // splits lose and stealing win. Quick mode shrinks it for CI.
    let (n, m, dmax) = if bench.quick() {
        (2_000, 12_000, 200)
    } else {
        (8_000, 64_000, 300)
    };
    let g = sort_by_degree_desc(&gen::power_law(n, m, dmax, 42)).graph;
    let roots = cpu::sampled_roots(g.num_vertices(), 1.0);
    let iters = if bench.quick() { 1 } else { 3 };

    let mut table = Table::new(
        &format!(
            "work-stealing CPU scaling — |V|={} |E|={} (seed 42, {} host cores)",
            g.num_vertices(),
            g.num_edges(),
            cores
        ),
        &["Workload", "Threads", "Time", "Speedup", "Tasks", "Steals", "Attempts"],
    );

    for app_name in ["CC", "4-MC"] {
        let app = application(app_name).unwrap();
        let plans = app.plans();
        let trie = PlanTrie::build(&plans);
        let mut serial_time = None;
        let mut serial_counts = None;
        for t in THREADS {
            let secs = bench.measure(&format!("cpu/{app_name}/t{t}"), 1, iters, || {
                cpu::count_plans_fused(
                    &g,
                    &trie,
                    &roots,
                    CpuFlavor::AutoMineOpt,
                    None,
                    None,
                    Some(t),
                )
            });
            // One telemetry pass per point: counts (checked against the
            // serial run) and the runtime's steal counters.
            let (counts, _, stats) = cpu::count_plans_fused_telemetry(
                &g,
                &trie,
                &roots,
                CpuFlavor::AutoMineOpt,
                None,
                None,
                Some(t),
            );
            let base_counts = serial_counts.get_or_insert_with(|| counts.clone());
            assert_eq!(
                &counts, base_counts,
                "{app_name}: counts diverged at {t} threads"
            );
            let base = *serial_time.get_or_insert(secs);
            let speedup = base / secs;
            bench.metric(&format!("{app_name} t{t} speedup"), speedup, "x");
            bench.metric(&format!("{app_name} t{t} steals"), stats.steals as f64, "steals");
            table.row(vec![
                app_name.to_string(),
                t.to_string(),
                report::s(secs),
                report::x(speedup),
                stats.tasks.to_string(),
                stats.steals.to_string(),
                stats.steal_attempts.to_string(),
            ]);
            // Acceptance: ≥2× at 4 threads on the clique ladder — only
            // meaningful where 4 workers have 4 cores to run on, and
            // quick mode's graph is too small to amortize spawn cost.
            if app_name == "CC" && t == 4 && cores >= 4 && !bench.quick() {
                assert!(
                    speedup >= 2.0,
                    "CC fused must scale ≥2x at 4 threads on a ≥4-core host, got {speedup:.2}x"
                );
            }
        }
    }

    // Imbalance micro: one straggler worker, three fast ones — the
    // steal counter must show the backlog moving (the same invariant
    // `tests/prop_parallel.rs` enforces, here reported as a metric).
    let tasks = 64;
    let done = AtomicU64::new(0);
    let (_, stats) = ws::run_tasks(
        4,
        tasks,
        |w| w,
        |w, _| {
            if *w == 0 {
                std::thread::sleep(std::time::Duration::from_micros(500));
            }
            done.fetch_add(1, Ordering::Relaxed);
        },
    );
    assert_eq!(done.load(Ordering::Relaxed), tasks as u64);
    bench.metric("imbalance_micro steals", stats.steals as f64, "steals");
    bench.metric(
        "imbalance_micro steal_attempts",
        stats.steal_attempts as f64,
        "attempts",
    );

    // Observability overhead gate (DESIGN.md §13): the disabled path of
    // a registry hook is one relaxed atomic load. Hammer a counter and a
    // histogram hook with the registry off and assert the amortized cost
    // stays in low single-digit nanoseconds — the "near-zero-cost when
    // disabled" budget the tracing/metrics subsystem promises.
    assert!(!metrics::enabled(), "registry must start disabled");
    let hook_iters: u64 = if bench.quick() { 2_000_000 } else { 20_000_000 };
    let t0 = std::time::Instant::now();
    for i in 0..hook_iters {
        metrics::SETOP_DENSE.add(std::hint::black_box(i));
        metrics::CAND_LEN.record(std::hint::black_box(i));
    }
    let per_hook_ns = t0.elapsed().as_nanos() as f64 / (2 * hook_iters) as f64;
    bench.metric("disabled_hook_ns", per_hook_ns, "ns");
    assert_eq!(metrics::SETOP_DENSE.get(), 0, "disabled hooks must not record");
    assert!(
        per_hook_ns < 10.0,
        "disabled observability hook costs {per_hook_ns:.2} ns, budget is 10 ns"
    );

    // End-to-end check on the same budget: the CC fused run with the
    // registry enabled vs disabled. The ratio is wall-clock noisy on
    // loaded CI hosts, so the hard assert is lenient; the metric records
    // the honest number for the perf trajectory.
    if cores >= 4 && !bench.quick() {
        let app = application("CC").unwrap();
        let plans = app.plans();
        let trie = PlanTrie::build(&plans);
        let run = || {
            cpu::count_plans_fused(&g, &trie, &roots, CpuFlavor::AutoMineOpt, None, None, Some(4))
        };
        let off = bench.measure("cpu/CC/t4 obs-off", 1, iters, run);
        metrics::reset();
        metrics::set_enabled(true);
        let on = bench.measure("cpu/CC/t4 obs-on", 1, iters, run);
        metrics::set_enabled(false);
        let ratio = on / off;
        bench.metric("obs_enabled_ratio", ratio, "x");
        assert!(
            ratio <= 1.5,
            "enabled observability slowed the fused run {ratio:.2}x (budget 1.5x)"
        );
    }

    // Zero-fault overhead gate (DESIGN.md §15): a benign fault spec
    // (no fail-stop, transient p = 0) must ride the fault-free fast
    // path — the whole SimResult bit-identical to `faults: None`, and
    // min-of-N wall time within 1.05×. Wall assert only in full mode;
    // quick mode's runs are too short to measure a 5% band honestly.
    {
        let app = application("3-CC").unwrap();
        let cfg = PimConfig::default();
        let sim_roots: Vec<u32> = (0..g.num_vertices() as u32).collect();
        let clean_opts = SimOptions {
            threads: Some(cores.min(4)),
            ..SimOptions::all()
        };
        let benign_opts = SimOptions {
            faults: Some(FaultSpec::default()),
            ..clean_opts
        };
        let reps = if bench.quick() { 3 } else { 5 };
        let min_wall = |opts: &SimOptions| {
            let mut best = f64::INFINITY;
            let mut out = None;
            for _ in 0..reps {
                let t0 = std::time::Instant::now();
                let r = simulate_app_checked(&g, &app, &sim_roots, opts, &cfg).unwrap();
                best = best.min(t0.elapsed().as_secs_f64());
                out = Some(r);
            }
            (best, out.unwrap())
        };
        let (t_clean, r_clean) = min_wall(&clean_opts);
        let (t_benign, r_benign) = min_wall(&benign_opts);
        assert_eq!(
            format!("{r_benign:?}"),
            format!("{r_clean:?}"),
            "benign fault spec perturbed the simulation result"
        );
        let ratio = t_benign / t_clean;
        bench.metric("zero_fault_overhead", ratio, "x");
        if !bench.quick() {
            assert!(
                ratio <= 1.05,
                "benign fault plumbing costs {ratio:.3}x wall time, budget is 1.05x"
            );
        }
    }

    table.print();
    if Bench::json_requested() {
        bench.write_json("BENCH_parallel.json").unwrap();
    }
}
