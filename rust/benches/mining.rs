//! Mining-workload bench (DESIGN.md §8) — beyond the paper's fixed
//! counting applications: the one-pass motif census and FSM on the
//! simulated machine, with the Table-2-style **support-aggregation**
//! traffic breakdown and its response to the address remap. Census counts
//! are asserted identical to the CPU engine on every graph.

use pimminer::bench::{workloads, Bench};
use pimminer::graph::gen;
use pimminer::mine::{self, FsmConfig};
use pimminer::pim::{simulate_fsm, simulate_motifs, PimConfig, SimOptions, SimResult};
use pimminer::report::{self, Table};

fn remote(r: &SimResult) -> u64 {
    r.agg.intra_bytes + r.agg.inter_bytes
}

fn main() {
    let bench = Bench::new("mining");
    let cfg = PimConfig::default();
    for inst in workloads::graphs(&["CI", "PP"]) {
        let g = &inst.graph;
        let roots: Vec<u32> = (0..g.num_vertices() as u32).collect();

        // ---- motif census: PIM vs CPU cross-check + per-config traffic
        for k in [3usize, 4] {
            let cpu = mine::motif_census(g, k, &roots);
            let mut table = Table::new(
                &format!(
                    "{k}-motif census on {} (|V|={}, {} patterns, {} subgraphs)",
                    inst.spec.abbrev,
                    g.num_vertices(),
                    cpu.counts.len(),
                    cpu.total()
                ),
                &["Config", "Time", "Near%", "AggNear%", "AggRemote", "MergeB"],
            );
            for (name, opts) in [
                ("Base", SimOptions::BASELINE),
                ("Full", SimOptions::all()),
            ] {
                let r = bench.fixture(&format!("census-k{k}-{}-{name}", inst.spec.abbrev), || {
                    simulate_motifs(g, k, &roots, &opts, &cfg)
                });
                assert_eq!(
                    r.census.counts, cpu.counts,
                    "PIM census diverged on {} k={k} ({name})",
                    inst.spec.abbrev
                );
                table.row(vec![
                    name.to_string(),
                    report::s(r.sim.seconds),
                    report::pct(r.sim.access.near_frac()),
                    report::pct(r.sim.agg.near_frac()),
                    report::bytes(remote(&r.sim)),
                    report::bytes(r.sim.agg_merge_bytes),
                ]);
            }
            table.print();
        }

        // ---- FSM on a labeled copy: the aggregation-heavy workload
        let labeled = gen::with_random_labels(g.clone(), 4, 7);
        let fsm_cfg = FsmConfig {
            min_support: (g.num_vertices() / 30).max(2) as u64,
            max_size: 3,
        };
        let mut table = Table::new(
            &format!(
                "FSM on {} (4 labels, support ≥ {}, max size {})",
                inst.spec.abbrev, fsm_cfg.min_support, fsm_cfg.max_size
            ),
            &["Config", "Frequent", "Time", "AggNear%", "AggRemote"],
        );
        let mut frequent_counts = Vec::new();
        for (name, opts) in [
            ("Base", SimOptions::BASELINE),
            ("Full", SimOptions::all()),
        ] {
            let (r, sim) = bench.fixture(&format!("fsm-{}-{name}", inst.spec.abbrev), || {
                simulate_fsm(&labeled, &fsm_cfg, &opts, &cfg)
            });
            frequent_counts.push(r.frequent.len());
            table.row(vec![
                name.to_string(),
                r.frequent.len().to_string(),
                report::s(sim.seconds),
                report::pct(sim.agg.near_frac()),
                report::bytes(remote(&sim)),
            ]);
        }
        assert_eq!(
            frequent_counts[0], frequent_counts[1],
            "optimizations must not change the mining result"
        );
        table.print();
    }
}
