//! Generalized-pattern bench: ad-hoc patterns through the pattern
//! compiler, executed on both the CPU baseline and the PIM `SimSink`
//! path. This is the workload class the fixed application catalogue
//! cannot cover — no paper table corresponds; it demonstrates the
//! framework property (README "beyond the paper"). Counts from the two
//! paths are asserted identical on every graph.

use pimminer::bench::{workloads, Bench};
use pimminer::exec::cpu::{self, CpuFlavor};
use pimminer::pattern::compile::{compile_with, parse_pattern, CostModel};
use pimminer::pim::{simulate_plan, PimConfig, SimOptions};
use pimminer::report::{self, Table};

/// Ad-hoc specs: raw edge lists and names, mixing 4- and 5-vertex shapes.
/// Dense-ish patterns only — sparse stars/paths explode combinatorially
/// on power-law graphs and teach nothing about the compiler.
const SPECS: [&str; 5] = [
    "0-1,1-2,2-0,2-3",             // tailed triangle (the acceptance spec)
    "0-1,0-2,0-3,1-2,2-3",         // diamond, as a raw edge list
    "house",                       // C5 + chord, by name
    "0-1,0-2,0-3,1-2,1-3,2-3,3-4", // tailed 4-clique
    "0-1,1-2,2-0,0-3,1-3,2-4,3-4", // 5-vertex ad-hoc (no common name)
];

fn main() {
    let bench = Bench::new("generalized_patterns");
    let cfg = PimConfig::default();
    for inst in workloads::graphs(&["CI", "MI"]) {
        let g = &inst.graph;
        let sample = workloads::sample_for("5-CC", inst.sample_ratio);
        let roots = cpu::sampled_roots(g.num_vertices(), sample);
        let model = CostModel::for_graph(g);
        let mut table = Table::new(
            &format!(
                "compiled patterns on {} (|V|={}, {} roots)",
                inst.spec.abbrev,
                g.num_vertices(),
                roots.len()
            ),
            &["Pattern", "Order", "EstCost", "Count", "CPU(s)", "PIM(s)", "Near%"],
        );
        for spec in SPECS {
            let compiled = parse_pattern(spec)
                .and_then(|p| compile_with(&p, &model, true))
                .expect("bench specs must compile");
            let label = compiled.plan.pattern.name.clone();
            let (cpu_s, cpu_count) = {
                let t = std::time::Instant::now();
                let c = cpu::count_plan(g, &compiled.plan, &roots, CpuFlavor::AutoMineOpt);
                (t.elapsed().as_secs_f64(), c)
            };
            let r = bench.fixture(&label, || {
                simulate_plan(g, &compiled.plan, &roots, &SimOptions::all(), &cfg)
            });
            assert_eq!(
                r.count, cpu_count,
                "CPU and PIM disagree on '{spec}' ({})",
                inst.spec.abbrev
            );
            table.row(vec![
                label,
                format!("{:?}", compiled.order),
                format!("{:.2e}", compiled.est_cost),
                r.count.to_string(),
                report::s(cpu_s),
                report::s(r.seconds),
                report::pct(r.access.near_frac()),
            ]);
        }
        table.print();
    }
}
