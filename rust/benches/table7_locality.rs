//! Table 7 reproduction: local (near-core) access ratio and speedup as
//! remapping and duplication are enabled on top of the filter (4-CC).
//! For PA/LJ the paper's 4 GB stack only fits a partial hot set (top 5% /
//! 0.25% of vertices); at bench scale we tighten the per-unit capacity to
//! induce the same partial-duplication regime.

use pimminer::baselines::published;
use pimminer::bench::{workloads, Bench};
use pimminer::exec::cpu;
use pimminer::graph::CsrGraph;
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::report::{self, pct, Table};

/// Per-unit capacity that fits ~`frac` of the hottest vertices as replicas.
fn capacity_for_fraction(g: &CsrGraph, cfg: &PimConfig, frac: f64) -> u64 {
    let top = (g.num_vertices() as f64 * frac) as u32;
    let replica_bytes: u64 = (0..top).map(|v| g.neighbor_bytes(v)).sum();
    g.total_bytes() / cfg.num_units() as u64 + replica_bytes
}

fn main() {
    let bench = Bench::new("table7_locality");
    let app = application("4-CC").unwrap();
    let cfg = PimConfig::default();
    let mut table = Table::new(
        "Table 7 — local access ratio & speedup (4-CC)",
        &[
            "Graph", "Base", "Remap", "Spd", "Dup", "Spd", "v_b/n",
            "paper Remap", "paper Dup",
        ],
    );
    for inst in workloads::graphs(&["CI", "PP", "AS", "MI", "YT", "PA", "LJ"]) {
        let g = &inst.graph;
        let roots = cpu::sampled_roots(g.num_vertices(), inst.sample_ratio);
        // Paper regime: PA duplicates the top 5%, LJ the top 0.25%; others
        // fit entirely. (At full scale the real 32 MB/unit capacity is
        // used instead.)
        let capacity = if pimminer::datasets::full_scale() {
            None
        } else {
            match inst.spec.abbrev {
                "PA" => Some(capacity_for_fraction(g, &cfg, 0.05)),
                "LJ" => Some(capacity_for_fraction(g, &cfg, 0.0025)),
                _ => None,
            }
        };
        let filter_only = SimOptions { filter: true, ..SimOptions::BASELINE };
        let remap = SimOptions { remap: true, ..filter_only };
        let dup = SimOptions {
            duplication: true,
            capacity_per_unit: capacity,
            ..remap
        };
        let (r0, r1, r2) = bench.fixture(inst.spec.abbrev, || {
            (
                simulate_app(g, &app, &roots, &filter_only, &cfg),
                simulate_app(g, &app, &roots, &remap, &cfg),
                simulate_app(g, &app, &roots, &dup, &cfg),
            )
        });
        let idx = published::GRAPHS
            .iter()
            .position(|&a| a == inst.spec.abbrev)
            .unwrap();
        let (_pb, prm, _prs, pdp, _pds) = published::TABLE7_LOCALITY[idx];
        table.row(vec![
            inst.spec.abbrev.to_string(),
            pct(r0.access.near_frac()),
            pct(r1.access.near_frac()),
            report::x(r0.seconds / r1.seconds),
            pct(r2.access.near_frac()),
            report::x(r1.seconds / r2.seconds),
            format!("{:.1}%", 100.0 * r2.v_b_min as f64 / g.num_vertices() as f64),
            format!("{prm:.2}%"),
            format!("{pdp:.2}%"),
        ]);
    }
    table.print();
}
