//! Fused vs per-plan A/B (DESIGN.md §11): CPU seconds, simulated cycles,
//! and fetched bytes for the multi-pattern workloads — 3-MC, 4-MC, the
//! CC clique ladder, and FSM — on the fixed-seed power-law bench graph.
//! Counts are asserted identical between the two modes, and fusion must
//! strictly cut simulated fetched bytes and cycles; `-- --json` writes
//! `BENCH_fusion.json` (`make bench` refreshes it, CI uploads it as an
//! artifact alongside the parity smoke).

use pimminer::bench::Bench;
use pimminer::exec::cpu::{self, CpuFlavor};
use pimminer::graph::{gen, sort_by_degree_desc};
use pimminer::mine::fsm::{fsm_mine_opts, FsmConfig};
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app, simulate_fsm, PimConfig, SimOptions, SimResult};
use pimminer::report::{self, Table};

fn main() {
    let bench = Bench::new("fusion");
    let cfg = PimConfig::default();
    // Fixed-seed power-law bench graph: strong hub skew, so the shared
    // loop prefixes carry real traffic. Quick mode shrinks it for CI.
    let (n, m, dmax) = if bench.quick() {
        (2_000, 12_000, 200)
    } else {
        (10_000, 80_000, 300)
    };
    let g = sort_by_degree_desc(&gen::power_law(n, m, dmax, 42)).graph;
    let roots = cpu::sampled_roots(g.num_vertices(), 1.0);
    let iters = if bench.quick() { 1 } else { 3 };

    let mut table = Table::new(
        &format!(
            "fused vs per-plan — |V|={} |E|={} (seed 42)",
            g.num_vertices(),
            g.num_edges()
        ),
        &[
            "Workload",
            "CPU sep",
            "CPU fused",
            "Speedup",
            "SimCy sep",
            "SimCy fused",
            "FM sep",
            "FM fused",
            "Shared",
        ],
    );

    // CC is the clique ladder (3/4/5-CC): its plans are nested prefixes,
    // so the fused trie is one path and the speedup is the headline
    // number. 4-MC's six plans diverge right after level 1 and ~98% of
    // their work sits in the unshared final levels, so its CPU ratio is
    // bounded near 1× by construction — its wins are the simulator's
    // traffic/cycle cuts (asserted below). DESIGN.md §11 quantifies both.
    for app_name in ["3-MC", "4-MC", "CC"] {
        let app = application(app_name).unwrap();
        let t_sep = bench.measure(&format!("cpu/{app_name}/per-plan"), 1, iters, || {
            cpu::run_application_with(
                &g,
                &app,
                &roots,
                CpuFlavor::AutoMineOpt,
                None,
                false,
                None,
                None,
            )
            .count
        });
        let t_fused = bench.measure(&format!("cpu/{app_name}/fused"), 1, iters, || {
            cpu::run_application_with(
                &g,
                &app,
                &roots,
                CpuFlavor::AutoMineOpt,
                None,
                true,
                None,
                None,
            )
            .count
        });
        bench.metric(&format!("{app_name} cpu_speedup"), t_sep / t_fused, "x");

        let sep = bench.fixture(&format!("sim/{app_name}/per-plan"), || {
            simulate_app(&g, &app, &roots, &SimOptions::all(), &cfg)
        });
        let fused_opts = SimOptions {
            fused: true,
            ..SimOptions::all()
        };
        let fus = bench.fixture(&format!("sim/{app_name}/fused"), || {
            simulate_app(&g, &app, &roots, &fused_opts, &cfg)
        });
        assert_eq!(sep.count, fus.count, "{app_name}: fused counts must match per-plan");
        assert!(
            fus.fm_bytes < sep.fm_bytes,
            "{app_name}: fusion must cut fetched bytes ({} vs {})",
            fus.fm_bytes,
            sep.fm_bytes
        );
        assert!(
            fus.total_cycles < sep.total_cycles,
            "{app_name}: fusion must cut simulated cycles ({} vs {})",
            fus.total_cycles,
            sep.total_cycles
        );
        bench.metric(
            &format!("{app_name} sim_cycle_speedup"),
            sep.total_cycles as f64 / fus.total_cycles as f64,
            "x",
        );
        bench.metric(
            &format!("{app_name} sim_fm_reduction"),
            sep.fm_bytes as f64 / fus.fm_bytes as f64,
            "x",
        );
        bench.metric(
            &format!("{app_name} shared_fetches"),
            fus.shared_fetches as f64,
            "fetches",
        );
        table.row(row(app_name, t_sep, t_fused, &sep, &fus));
    }

    // ---- FSM: fused level evaluation vs per-candidate ----
    let (lv, le) = if bench.quick() {
        (800, 4_000)
    } else {
        (2_000, 12_000)
    };
    let lg = sort_by_degree_desc(&gen::with_random_labels(
        gen::power_law(lv, le, 120, 42),
        4,
        7,
    ))
    .graph;
    let fsm_cfg = FsmConfig {
        min_support: (lg.num_vertices() / 30).max(2) as u64,
        max_size: 3,
    };
    let t_sep = bench.measure("cpu/FSM/per-candidate", 1, iters, || {
        fsm_mine_opts(&lg, &fsm_cfg, None, false, None).frequent.len()
    });
    let t_fused = bench.measure("cpu/FSM/fused", 1, iters, || {
        fsm_mine_opts(&lg, &fsm_cfg, None, true, None).frequent.len()
    });
    bench.metric("FSM cpu_speedup", t_sep / t_fused, "x");
    let (r_sep, s_sep) = bench.fixture("sim/FSM/per-candidate", || {
        simulate_fsm(&lg, &fsm_cfg, &SimOptions::all(), &cfg)
    });
    let (r_fus, s_fus) = bench.fixture("sim/FSM/fused", || {
        simulate_fsm(
            &lg,
            &fsm_cfg,
            &SimOptions {
                fused: true,
                ..SimOptions::all()
            },
            &cfg,
        )
    });
    assert_eq!(r_sep.frequent.len(), r_fus.frequent.len(), "FSM results must match");
    assert!(
        s_fus.fm_bytes < s_sep.fm_bytes,
        "FSM: fusion must cut fetched bytes ({} vs {})",
        s_fus.fm_bytes,
        s_sep.fm_bytes
    );
    bench.metric(
        "FSM sim_cycle_speedup",
        s_sep.total_cycles as f64 / s_fus.total_cycles as f64,
        "x",
    );
    bench.metric("FSM shared_fetches", s_fus.shared_fetches as f64, "fetches");
    table.row(row("FSM", t_sep, t_fused, &s_sep, &s_fus));

    table.print();
    if Bench::json_requested() {
        bench.write_json("BENCH_fusion.json").unwrap();
    }
}

fn row(name: &str, t_sep: f64, t_fused: f64, sep: &SimResult, fus: &SimResult) -> Vec<String> {
    vec![
        name.to_string(),
        report::s(t_sep),
        report::s(t_fused),
        report::x(t_sep / t_fused),
        sep.total_cycles.to_string(),
        fus.total_cycles.to_string(),
        report::bytes(sep.fm_bytes),
        report::bytes(fus.fm_bytes),
        fus.shared_fetches.to_string(),
    ]
}
