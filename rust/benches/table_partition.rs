//! Partitioning-strategy comparison (DESIGN.md §9): the Table-2-style
//! remote-byte breakdown of round-robin vs. streaming vs. refined owner
//! maps on power-law and Erdős–Rényi graphs, at equal replica capacity,
//! under the local-first mapping.
//!
//! `cargo bench --bench table_partition -- --json` (or
//! `PIMMINER_BENCH_JSON=1`) additionally writes `BENCH_partition.json`
//! with the remote-byte shares — the machine-readable mode CI consumes.

use pimminer::bench::Bench;
use pimminer::graph::{gen, sort_by_degree_desc, CsrGraph};
use pimminer::part::PartitionStrategy;
use pimminer::pattern::plan::application;
use pimminer::pim::{build_placement, simulate_app, PimConfig, SimOptions};
use pimminer::report::{bytes, json, pct, Table};

fn graphs() -> Vec<(&'static str, CsrGraph)> {
    vec![
        ("power-law(2k,10k)", sort_by_degree_desc(&gen::power_law(2_000, 10_000, 300, 8)).graph),
        ("power-law(4k,24k)", sort_by_degree_desc(&gen::power_law(4_000, 24_000, 400, 19)).graph),
        ("erdos-renyi(2k,10k)", sort_by_degree_desc(&gen::erdos_renyi(2_000, 10_000, 7)).graph),
    ]
}

fn main() {
    let bench = Bench::new("table_partition");
    let json_mode = std::env::args().any(|a| a == "--json")
        || std::env::var("PIMMINER_BENCH_JSON").map(|v| v == "1").unwrap_or(false);
    let cfg = PimConfig::default();
    let app = application("3-CC").unwrap();
    let mut table = Table::new(
        "Partitioning — access distribution under LocalFirst, 3-CC, equal replica capacity",
        &["Graph", "Strategy", "Near", "Intra", "Inter", "InterBytes", "ReplicaB", "vs RR"],
    );
    let mut json_rows: Vec<String> = Vec::new();
    for (name, g) in graphs() {
        let roots: Vec<u32> = (0..g.num_vertices() as u32).collect();
        // Equal replica capacity for every strategy: own share + 10%.
        let cap = g.total_bytes() / cfg.num_units() as u64 + g.total_bytes() / 10;
        let mut rr_inter = None;
        for strategy in PartitionStrategy::ALL {
            let opts = SimOptions {
                filter: true,
                remap: true, // AddrMap::LocalFirst
                duplication: true,
                capacity_per_unit: Some(cap),
                partitioner: strategy,
                ..SimOptions::BASELINE
            };
            let r = bench.fixture(&format!("{name}/{}", strategy.name()), || {
                simulate_app(&g, &app, &roots, &opts, &cfg)
            });
            let base = *rr_inter.get_or_insert(r.access.inter_bytes);
            let reduction = 1.0 - r.access.inter_bytes as f64 / base.max(1) as f64;
            if strategy == PartitionStrategy::Refined {
                // the integration-test acceptance bar, asserted here too
                assert!(
                    r.access.inter_bytes * 4 <= base * 3,
                    "{name}: refined inter bytes {} not ≥25% below round-robin {base}",
                    r.access.inter_bytes
                );
            }
            let rep = build_placement(&g, &opts, &cfg).replica_report(&g);
            table.row(vec![
                name.to_string(),
                strategy.name().to_string(),
                pct(r.access.near_frac()),
                pct(r.access.intra_frac()),
                pct(r.access.inter_frac()),
                bytes(r.access.inter_bytes),
                bytes(rep.total_bytes),
                format!("-{:.1}%", reduction * 100.0),
            ]);
            json_rows.push(
                json::Obj::new()
                    .str("graph", name)
                    .str("strategy", strategy.name())
                    .f64("near_share", r.access.near_frac())
                    .f64("intra_share", r.access.intra_frac())
                    .f64("inter_share", r.access.inter_frac())
                    .u64("near_bytes", r.access.near_bytes)
                    .u64("intra_bytes", r.access.intra_bytes)
                    .u64("inter_bytes", r.access.inter_bytes)
                    .f64("inter_reduction_vs_rr", reduction)
                    .u64("replica_bytes", rep.total_bytes)
                    .f64("seconds", r.seconds)
                    .render(),
            );
        }
    }
    table.print();
    if json_mode {
        let doc = json::Obj::new()
            .str("bench", "table_partition")
            .raw("rows", &json::array(&json_rows))
            .render();
        std::fs::write("BENCH_partition.json", doc).expect("write BENCH_partition.json");
        println!("wrote BENCH_partition.json ({} rows)", json_rows.len());
    }
}
