"""AOT lowering: JAX → HLO text artifacts for the Rust runtime.

Interchange is HLO **text**, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which this image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md and
gen_hlo.py there).

Artifacts (shapes are static; the Rust tiler pads to them):
  * ``setops.hlo.txt`` — the Pallas-kernel path (Layer 1 inside Layer 2).
  * ``model.hlo.txt``  — the pure-jnp reference path (Layer 2 only).

Tile shape defaults to B=64, L=256; override with PIMMINER_KERNEL_B /
PIMMINER_KERNEL_L at build time (the Rust side reads the same envs).

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def tile_shape():
    b = int(os.environ.get("PIMMINER_KERNEL_B", "64"))
    length = int(os.environ.get("PIMMINER_KERNEL_L", "256"))
    return b, length


def lower_artifacts():
    """Lower both artifacts; returns {name: hlo_text}."""
    b, length = tile_shape()
    lists = jax.ShapeDtypeStruct((b, length), jnp.int32)
    ths = jax.ShapeDtypeStruct((b,), jnp.int32)
    arts = {}
    arts["setops.hlo.txt"] = to_hlo_text(
        jax.jit(model.setops_model).lower(lists, lists, ths)
    )
    arts["model.hlo.txt"] = to_hlo_text(
        jax.jit(model.setops_reference_model).lower(lists, lists, ths)
    )
    return arts


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    b, length = tile_shape()
    for name, text in lower_artifacts().items():
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars, tile B={b} L={length})")


if __name__ == "__main__":
    main()
