"""Layer-2 JAX compute graph.

The PIMMiner "model" is the batched set-operation engine the PIM units
execute: given a tile of candidate neighbor-list pairs and per-pair
symmetry-breaking thresholds, produce filtered intersection/subtraction
counts. ``setops_model`` routes through the Layer-1 Pallas kernel;
``setops_reference_model`` is the pure-jnp equivalent, exported as its own
artifact so the Rust integration tests can cross-check the two lowered
paths against each other *and* against the native Rust implementation.

``triangle_tile_count`` composes the kernel the way `PIMPatternCount`
uses it for 3-CC: for edge (u, v) with v < u, triangles though that edge
= |{w ∈ N(u) ∩ N(v) : w < v}| (the paper's Fig. 2 restriction chain).
"""

import jax.numpy as jnp

from .kernels import filtered_intersect
from .kernels import ref


def setops_model(a, b, th):
    """(B,L),(B,L),(B,) -> ((B,), (B,)) via the Pallas kernel."""
    return filtered_intersect.filtered_setops(a, b, th)


def setops_reference_model(a, b, th):
    """Same contract, pure jnp (no Pallas) — the L2 reference artifact."""
    return ref.filtered_setops_ref(a, b, th)


def triangle_tile_count(a, b, th):
    """Triangles across a tile of edges: sum of filtered intersections.

    Returns (total, per_edge) so callers can either reduce or inspect.
    """
    inter, _ = setops_model(a, b, th)
    return jnp.sum(inter, dtype=jnp.int32), inter
