"""Pure-jnp oracle for the Layer-1 kernels.

Semantics (shared with the Pallas kernel and the Rust ``exec::setops``
implementation):

* ``a``, ``b`` are ``(B, L)`` int32 tiles; each row is a strictly-ascending
  sorted list (a vertex neighbor list) padded at the tail with ``PAD``.
* ``th`` is ``(B,)`` int32: the exclusive symmetry-breaking upper bound the
  paper's in-bank filter applies (``cmp='<'``).
* outputs: per-row filtered intersection and subtraction counts,

      inter[i] = |{x in a[i] ∩ b[i] : x < th[i]}|
      sub[i]   = |{x in a[i] \\ b[i] : x < th[i]}|

The O(L²) broadcast-compare here is the correctness reference; pytest
checks the Pallas kernel (and, transitively, the Rust runtime path)
against it.
"""

import jax.numpy as jnp

PAD = jnp.iinfo(jnp.int32).max


def filtered_setops_ref(a, b, th):
    """Reference filtered intersection/subtraction counts.

    Args:
      a: (B, L) int32, sorted ascending rows, PAD-padded.
      b: (B, L) int32, sorted ascending rows, PAD-padded.
      th: (B,) int32 exclusive upper bound per row.

    Returns:
      (inter, sub): two (B,) int32 arrays.
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    th = jnp.asarray(th, jnp.int32)
    valid = (a != PAD) & (a < th[:, None])
    member = (a[:, :, None] == b[:, None, :]).any(axis=-1)
    inter = jnp.sum(valid & member, axis=-1).astype(jnp.int32)
    sub = jnp.sum(valid & ~member, axis=-1).astype(jnp.int32)
    return inter, sub


def filtered_setops_py(a_row, b_row, th):
    """Plain-Python scalar reference for a single pair of lists (a second,
    jnp-free opinion used by the tests)."""
    pad = int(PAD)
    bs = set(int(x) for x in b_row if int(x) != pad)
    inter = 0
    sub = 0
    for x in a_row:
        x = int(x)
        if x == pad or x >= th:
            continue
        if x in bs:
            inter += 1
        else:
            sub += 1
    return inter, sub
