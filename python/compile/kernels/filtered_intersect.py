"""Layer-1 Pallas kernel: batched filtered set intersection/subtraction.

This is the compute hot-spot of pattern enumeration (§2.1.2's I/S
operations) with the paper's in-bank filter (§4.2) fused in: elements
failing ``x < th`` are masked before they contribute to any count, the
software analogue of dropping them at the sense amplifiers.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's PIM unit
streams a neighbor list from its near bank; on TPU the analogue is a VMEM
tile processed by the VPU. Instead of a sequential sorted-merge (great on
an in-order PIM core, terrible on a vector unit), the kernel does a
blocked broadcast-compare: each grid step holds one ``(BB, L)`` tile pair
in VMEM and evaluates the ``(BB, LA_BLOCK, L)`` equality cube with vector
ops. ``BlockSpec`` expresses the HBM→VMEM schedule that the paper
expresses with bank-group placement.

Always lowered with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PAD

# Default VMEM batch block: 8 rows × L=256 → the compare cube is
# 8·64·256·4B = 512 KiB, comfortably inside a TPU core's ~16 MiB VMEM
# alongside the operand tiles. (interpret=True on CPU ignores VMEM, but
# the BlockSpec is written for the real schedule.)
DEFAULT_BLOCK_B = 8
# Inner blocking of the `a` axis keeps the compare cube bounded for
# larger L without spilling: the cube is (BB, A_BLOCK, L).
DEFAULT_BLOCK_A = 64


def _setops_kernel(a_ref, b_ref, th_ref, inter_ref, sub_ref, *, block_a):
    """One grid step: full rows for a block of the batch dimension."""
    a = a_ref[...]          # (BB, L) int32
    b = b_ref[...]          # (BB, L) int32
    th = th_ref[...]        # (BB,)   int32
    bb, length = a.shape

    inter_acc = jnp.zeros((bb,), jnp.int32)
    sub_acc = jnp.zeros((bb,), jnp.int32)
    # Statically-unrolled blocking over the `a` axis: LA_BLOCK columns of
    # `a` are compared against all of `b` per step.
    for start in range(0, length, block_a):
        a_blk = a[:, start : start + block_a]            # (BB, A)
        valid = (a_blk != PAD) & (a_blk < th[:, None])   # (BB, A)
        member = (a_blk[:, :, None] == b[:, None, :]).any(axis=-1)  # (BB, A)
        inter_acc = inter_acc + jnp.sum(valid & member, axis=-1, dtype=jnp.int32)
        sub_acc = sub_acc + jnp.sum(valid & ~member, axis=-1, dtype=jnp.int32)
    inter_ref[...] = inter_acc
    sub_ref[...] = sub_acc


@functools.partial(jax.jit, static_argnames=("block_b", "block_a"))
def filtered_setops(a, b, th, block_b=DEFAULT_BLOCK_B, block_a=DEFAULT_BLOCK_A):
    """Batched filtered intersection/subtraction counts via Pallas.

    Args / returns: identical to ``ref.filtered_setops_ref``.
    The batch dimension must be divisible by ``block_b`` (aot.py and the
    Rust tiler always send full tiles).
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    th = jnp.asarray(th, jnp.int32)
    batch, length = a.shape
    assert b.shape == (batch, length), (a.shape, b.shape)
    assert th.shape == (batch,), th.shape
    bb = min(block_b, batch)
    assert batch % bb == 0, f"batch {batch} not divisible by block {bb}"
    ba = min(block_a, length)

    grid = (batch // bb,)
    kernel = functools.partial(_setops_kernel, block_a=ba)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, length), lambda i: (i, 0)),
            pl.BlockSpec((bb, length), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, b, th)


def vmem_bytes_estimate(block_b, length, block_a):
    """Static VMEM footprint estimate for one grid step (DESIGN.md §Perf):
    operand tiles + compare cube + accumulators, in bytes."""
    operands = 2 * block_b * length * 4 + block_b * 4
    cube = block_b * block_a * length * 4
    accs = 2 * block_b * 4
    return operands + cube + accs
