"""Layer-2 tests: the model graph composes the kernel correctly, the two
artifact paths (Pallas vs pure-jnp) agree, and the AOT lowering emits
loadable HLO text."""

import numpy as np

from compile import aot, model
from compile.kernels.ref import PAD

PADI = int(PAD)


def tile_from_lists(pairs, length):
    batch = len(pairs)
    a = np.full((batch, length), PADI, np.int32)
    b = np.full((batch, length), PADI, np.int32)
    th = np.zeros((batch,), np.int32)
    for i, (la, lb, t) in enumerate(pairs):
        a[i, : len(la)] = la
        b[i, : len(lb)] = lb
        th[i] = t
    return a, b, th


def test_model_paths_agree():
    rng = np.random.default_rng(3)
    batch, length = 16, 64
    a = np.full((batch, length), PADI, np.int32)
    b = np.full((batch, length), PADI, np.int32)
    for i in range(batch):
        na, nb = rng.integers(0, length, 2)
        a[i, :na] = np.sort(rng.choice(500, na, replace=False))
        b[i, :nb] = np.sort(rng.choice(500, nb, replace=False))
    th = rng.integers(0, 500, batch).astype(np.int32)
    ki, ks = model.setops_model(a, b, th)
    ri, rs = model.setops_reference_model(a, b, th)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))


def test_triangle_tile_count_known_graph():
    # K4 with degree-descending ids: N(0)={1,2,3}, N(1)={0,2,3}, etc.
    # Edges (u,v), v<u; triangles per edge = |{w in N(u)∩N(v): w<v}|.
    neigh = {
        0: [1, 2, 3],
        1: [0, 2, 3],
        2: [0, 1, 3],
        3: [0, 1, 2],
    }
    edges = [(u, v) for u in neigh for v in neigh[u] if v < u]
    pairs = [(neigh[u], neigh[v], v) for (u, v) in edges]
    a, b, th = tile_from_lists(pairs, 8)
    # pad batch to a block multiple
    pad_rows = 8 - len(pairs) % 8 if len(pairs) % 8 else 0
    if pad_rows:
        a = np.vstack([a, np.full((pad_rows, 8), PADI, np.int32)])
        b = np.vstack([b, np.full((pad_rows, 8), PADI, np.int32)])
        th = np.concatenate([th, np.zeros(pad_rows, np.int32)])
    total, per_edge = model.triangle_tile_count(a, b, th)
    # K4 has 4 triangles, each counted exactly once by the restriction chain
    assert int(total) == 4
    assert int(np.asarray(per_edge).sum()) == 4


def test_aot_lowering_produces_hlo_text():
    arts = aot.lower_artifacts()
    assert set(arts) == {"setops.hlo.txt", "model.hlo.txt"}
    for name, text in arts.items():
        assert "HloModule" in text, f"{name} missing HloModule header"
        assert len(text) > 1000, f"{name} suspiciously small"


def test_tile_shape_env(monkeypatch):
    monkeypatch.setenv("PIMMINER_KERNEL_B", "16")
    monkeypatch.setenv("PIMMINER_KERNEL_L", "32")
    assert aot.tile_shape() == (16, 32)
