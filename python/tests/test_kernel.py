"""Layer-1 correctness: the Pallas kernel vs the pure-jnp oracle vs a
plain-Python scalar reference. Hypothesis sweeps shapes, paddings, and
thresholds — this is the core correctness signal for the compute layer."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.filtered_intersect import (
    filtered_setops,
    vmem_bytes_estimate,
    DEFAULT_BLOCK_B,
)
from compile.kernels.ref import PAD, filtered_setops_ref, filtered_setops_py

PADI = int(PAD)


def make_tile(rng, batch, length, max_id, fill=0.7):
    """Random (batch, length) tile of strictly-ascending PAD-padded rows."""
    out = np.full((batch, length), PADI, dtype=np.int32)
    for i in range(batch):
        n = int(rng.integers(0, int(length * fill) + 1))
        if n:
            # unique ascending sample without materializing range(max_id)
            vals = np.unique(rng.integers(0, max_id, size=2 * n))[:n]
            out[i, : len(vals)] = vals.astype(np.int32)
    return out


def assert_kernel_matches(a, b, th, block_b=DEFAULT_BLOCK_B, block_a=64):
    got_i, got_s = filtered_setops(a, b, th, block_b=block_b, block_a=block_a)
    ref_i, ref_s = filtered_setops_ref(a, b, th)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(ref_i))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(ref_s))
    # spot-check rows against the jnp-free reference
    for i in range(min(len(th), 4)):
        pi, ps = filtered_setops_py(a[i], b[i], int(th[i]))
        assert int(got_i[i]) == pi
        assert int(got_s[i]) == ps


def test_simple_known_case():
    a = np.full((8, 16), PADI, np.int32)
    b = np.full((8, 16), PADI, np.int32)
    a[0, :5] = [1, 3, 5, 7, 9]
    b[0, :4] = [3, 4, 5, 10]
    th = np.full((8,), 8, np.int32)
    inter, sub = filtered_setops(a, b, th)
    assert int(inter[0]) == 2  # {3, 5}
    assert int(sub[0]) == 2    # {1, 7}
    # empty rows
    assert int(inter[1]) == 0 and int(sub[1]) == 0


def test_threshold_edges():
    a = np.full((8, 8), PADI, np.int32)
    b = np.full((8, 8), PADI, np.int32)
    a[:, :3] = [10, 20, 30]
    b[:, :2] = [20, 40]
    # th=0 filters everything; th=MAX keeps everything
    th = np.array([0, 10, 11, 20, 21, 31, PADI, PADI - 1], np.int32)
    inter, sub = filtered_setops(a, b, th)
    exp = [filtered_setops_py(a[i], b[i], int(th[i])) for i in range(8)]
    assert [int(x) for x in inter] == [e[0] for e in exp]
    assert [int(x) for x in sub] == [e[1] for e in exp]


def test_identical_lists_all_intersect():
    rng = np.random.default_rng(0)
    a = make_tile(rng, 8, 32, 1000)
    th = np.full((8,), PADI, np.int32)
    inter, sub = filtered_setops(a, a, th)
    lens = (a != PADI).sum(axis=1)
    np.testing.assert_array_equal(np.asarray(inter), lens.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(sub), np.zeros(8, np.int32))


def test_disjoint_lists_all_subtract():
    a = np.full((8, 8), PADI, np.int32)
    b = np.full((8, 8), PADI, np.int32)
    a[:, :4] = [0, 2, 4, 6]
    b[:, :4] = [1, 3, 5, 7]
    th = np.full((8,), 100, np.int32)
    inter, sub = filtered_setops(a, b, th)
    assert all(int(x) == 0 for x in inter)
    assert all(int(x) == 4 for x in sub)


@settings(max_examples=40, deadline=None)
@given(
    batch_blocks=st.integers(1, 4),
    length=st.sampled_from([8, 64, 128, 256]),
    max_id=st.sampled_from([50, 1000, 2**31 - 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_property(batch_blocks, length, max_id, seed):
    rng = np.random.default_rng(seed)
    batch = DEFAULT_BLOCK_B * batch_blocks
    a = make_tile(rng, batch, length, max_id)
    b = make_tile(rng, batch, length, max_id)
    th = rng.integers(0, max_id + 1, size=batch).astype(np.int32)
    assert_kernel_matches(a, b, th)


@settings(max_examples=10, deadline=None)
@given(
    block_a=st.sampled_from([8, 32, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_a_invariance(block_a, seed):
    """Counts must be independent of the inner a-axis blocking."""
    rng = np.random.default_rng(seed)
    a = make_tile(rng, 8, 256, 5000)
    b = make_tile(rng, 8, 256, 5000)
    th = rng.integers(0, 5000, size=8).astype(np.int32)
    assert_kernel_matches(a, b, th, block_a=block_a)


def test_block_b_invariance():
    rng = np.random.default_rng(7)
    a = make_tile(rng, 16, 64, 500)
    b = make_tile(rng, 16, 64, 500)
    th = rng.integers(0, 500, size=16).astype(np.int32)
    r1 = filtered_setops(a, b, th, block_b=8)
    r2 = filtered_setops(a, b, th, block_b=16)
    r4 = filtered_setops(a, b, th, block_b=4)
    for x, y in [(r1, r2), (r1, r4)]:
        np.testing.assert_array_equal(np.asarray(x[0]), np.asarray(y[0]))
        np.testing.assert_array_equal(np.asarray(x[1]), np.asarray(y[1]))


def test_indivisible_batch_rejected():
    a = np.full((3, 8), PADI, np.int32)
    th = np.zeros((3,), np.int32)
    with pytest.raises(AssertionError):
        filtered_setops(a, a, th, block_b=2)


def test_vmem_estimate_within_budget():
    # The default BlockSpec must fit a TPU core's VMEM with ample slack.
    est = vmem_bytes_estimate(DEFAULT_BLOCK_B, 256, 64)
    assert est < 4 * 2**20, f"VMEM estimate {est} too large"
