//! Quickstart: the full PIMMiner API surface on a small graph in ~40
//! lines — generate, `PIMLoadGraph`, verify the device contents, and
//! `PIMPatternCount` with the complete optimization stack.
//!
//! Run: `cargo run --release --example quickstart`

use pimminer::coordinator::PimMiner;
use pimminer::graph::{gen, sort_by_degree_desc};
use pimminer::pattern::plan::application;
use pimminer::pim::{PimConfig, SimOptions};
use pimminer::report;

fn main() -> anyhow::Result<()> {
    // 1. A small power-law graph, degree-sorted (the paper's preprocessing).
    let raw = gen::power_law(5_000, 30_000, 400, 1);
    let graph = sort_by_degree_desc(&raw).graph;
    println!(
        "graph: |V|={} |E|={} max-degree={}",
        graph.num_vertices(),
        graph.num_edges(),
        graph.max_degree()
    );

    // 2. PIMLoadGraph: round-robin placement + hot-vertex duplication.
    let mut miner = PimMiner::new(PimConfig::default(), SimOptions::all());
    miner.load_graph(graph)?;
    miner.verify_device_contents()?;
    let v_b = miner.loaded().unwrap().placement.v_b[0];
    println!("loaded into 128 PIM units; duplication boundary v_b = {v_b}");

    // 3. PIMPatternCount for each paper application.
    for name in ["3-CC", "4-CC", "3-MC", "4-DI", "4-CL"] {
        let app = application(name).unwrap();
        let r = miner.pattern_count(&app, 1.0)?;
        println!(
            "{:>5}: count={:>10}  sim time={}  near={}  steals={}",
            name,
            r.count,
            report::s(r.seconds),
            report::pct(r.access.near_frac()),
            r.steals
        );
    }
    Ok(())
}
