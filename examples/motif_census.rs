//! Motif census — the bioinformatics workload from the paper's intro
//! (§1: motif extraction from gene networks): a full 3- and 4-motif
//! census over a protein-interaction-like graph, on both the CPU baseline
//! and PIMMiner, reporting per-motif counts and the PIM speedup.
//!
//! Run: `cargo run --release --example motif_census`

use pimminer::coordinator::PimMiner;
use pimminer::exec::cpu::{self, CpuFlavor};
use pimminer::graph::{gen, sort_by_degree_desc};
use pimminer::pattern::motif::connected_motifs;
use pimminer::pattern::plan::Application;
use pimminer::pim::{PimConfig, SimOptions};
use pimminer::report::{self, Table};

fn main() -> anyhow::Result<()> {
    // A PPI-network-like graph: sparse, heavy-tailed.
    let raw = gen::power_law(8_000, 36_000, 500, 7);
    let graph = sort_by_degree_desc(&raw).graph;
    let roots: Vec<u32> = (0..graph.num_vertices() as u32).collect();
    println!(
        "census graph: |V|={} |E|={}",
        graph.num_vertices(),
        graph.num_edges()
    );

    let mut miner = PimMiner::new(PimConfig::default(), SimOptions::all());
    miner.load_graph(graph.clone())?;

    let mut table = Table::new(
        "3/4-motif census (induced counts)",
        &["Motif", "Edges", "Count", "CPU time", "PIM time", "Speedup*"],
    );
    for k in [3usize, 4] {
        for motif in connected_motifs(k) {
            let app = Application {
                name: "census",
                patterns: vec![motif.clone()],
            };
            let cpu_r = cpu::run_application(&graph, &app, &roots, CpuFlavor::AutoMineOpt);
            let pim_r = miner.pattern_count(&app, 1.0)?;
            assert_eq!(cpu_r.count, pim_r.count, "CPU/PIM disagree on {}", motif.name);
            table.row(vec![
                motif.name.clone(),
                motif.num_edges().to_string(),
                pim_r.count.to_string(),
                report::s(cpu_r.seconds),
                report::s(pim_r.seconds),
                report::x(cpu_r.seconds / pim_r.seconds),
            ]);
        }
    }
    table.print();
    println!("* CPU measured on this host; PIM simulated at Table 4 parameters.");
    Ok(())
}
