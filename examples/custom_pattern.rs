//! Custom-pattern mining: the pattern compiler end to end.
//!
//!   1. parse a user-supplied pattern spec (edge list or name), compile it
//!      — automorphism-based symmetry breaking + cost-driven matching
//!      order — and print the resulting plan;
//!   2. prove the plan correct against the brute-force reference
//!      enumerator on seeded random graphs;
//!   3. mine the pattern on a MiCo-class graph through both the CPU
//!      baseline and the full PIM optimization stack, counts cross-checked.
//!
//! Run: `cargo run --release --example custom_pattern -- --pattern "0-1,1-2,2-0,2-3"`
//! (or any name the compiler knows: `--pattern house`).

use pimminer::exec::brute_force_count;
use pimminer::exec::cpu::{self, CpuFlavor};
use pimminer::graph::{gen, sort_by_degree_desc};
use pimminer::pattern::compile::{compile_with, parse_pattern, CostModel};
use pimminer::pim::{simulate_plan, PimConfig, SimOptions};
use pimminer::report::{self, Table};
use pimminer::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let spec = args.get_or("pattern", "0-1,1-2,2-0,2-3");

    // ---- 1. compile and show the plan
    let pattern = match parse_pattern(spec) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("pattern error: {e}");
            std::process::exit(2);
        }
    };
    let model = CostModel::default();
    let compiled = compile_with(&pattern, &model, true).expect("connected pattern");
    println!(
        "compiled '{}': {} vertices, |Aut| = {}, {} restrictions, order {:?}, est cost {:.3e}",
        compiled.plan.pattern.name,
        compiled.plan.size(),
        compiled.plan.aut_count,
        compiled.num_restrictions(),
        compiled.order,
        compiled.est_cost
    );

    // ---- 2. correctness: brute-force cross-check on small random graphs
    for seed in [1u64, 2, 3] {
        let g = gen::erdos_renyi(14, 34, seed);
        let expected = brute_force_count(&g, &compiled.plan.pattern);
        let roots: Vec<u32> = (0..g.num_vertices() as u32).collect();
        let got = cpu::count_plan(&g, &compiled.plan, &roots, CpuFlavor::AutoMineOpt);
        assert_eq!(got, expected, "seed {seed}");
        println!("  brute-force check, ER(14,34) seed {seed}: {expected} embeddings — OK");
    }

    // ---- 3. mine it on a MiCo-class graph, CPU vs PIM ladder
    let raw = gen::power_law(20_000, 200_000, 600, 42);
    let g = sort_by_degree_desc(&raw).graph;
    let model = CostModel::for_graph(&g);
    let compiled = compile_with(&pattern, &model, true).expect("connected pattern");
    let roots = cpu::sampled_roots(g.num_vertices(), 0.2);
    println!(
        "\nmining on |V|={} |E|={} ({} roots), order {:?}",
        g.num_vertices(),
        g.num_edges(),
        roots.len(),
        compiled.order
    );

    let t = std::time::Instant::now();
    let cpu_count = cpu::count_plan(&g, &compiled.plan, &roots, CpuFlavor::AutoMineOpt);
    let cpu_s = t.elapsed().as_secs_f64();
    println!("CPU baseline: count={cpu_count} in {}", report::s(cpu_s));

    let cfg = PimConfig::default();
    let mut table = Table::new(
        &format!("PIM ladder — {}", compiled.plan.pattern.name),
        &["Config", "Count", "Total", "Near%", "Steals", "Speedup"],
    );
    let mut base = None;
    for (name, opts) in SimOptions::ladder() {
        let r = simulate_plan(&g, &compiled.plan, &roots, &opts, &cfg);
        assert_eq!(r.count, cpu_count, "PIM count diverged under {name}");
        let b = *base.get_or_insert(r.seconds);
        table.row(vec![
            name.to_string(),
            r.count.to_string(),
            report::s(r.seconds),
            report::pct(r.access.near_frac()),
            r.steals.to_string(),
            report::x(b / r.seconds),
        ]);
    }
    table.print();
    println!("CPU and PIM agree across the whole ladder — compiler OK");
}
