//! End-to-end driver (DESIGN.md §7): proves all three layers compose on a
//! realistic workload.
//!
//!   1. generate a MiCo-class graph, load it through `PIMLoadGraph`;
//!   2. run `PIMPatternCount` (4-CC) on the HBM-PIM simulator with the
//!      full optimization ladder (the Fig. 9 experiment);
//!   3. cross-check the embedding count against (a) the multithreaded CPU
//!      executor and (b) the AOT Pallas artifact executed via PJRT from
//!      Rust (triangle closure over the level-2 frontier) — all three
//!      mechanisms must agree exactly;
//!   4. report throughput for the batched kernel path.
//!
//! Requires `make artifacts` (skips step 3 politely if missing).
//! Run: `cargo run --release --example end_to_end`

use pimminer::coordinator::PimMiner;
use pimminer::exec::cpu::{self, CpuFlavor};
use pimminer::graph::{gen, sort_by_degree_desc};
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::report::{self, Table};
use pimminer::runtime::{artifacts_available, artifacts_dir, Runtime, SetOpRequest, SetOpsKernel};

const KERNEL_B: usize = 64;
const KERNEL_L: usize = 256;

fn main() -> anyhow::Result<()> {
    // ---- 1. workload: MiCo-scaled graph, degree capped to the kernel tile
    let raw = gen::power_law(15_000, 220_000, KERNEL_L - 2, 2023);
    let capped = gen::cap_degree(&raw, KERNEL_L); // respect the AOT tile bound
    let graph = sort_by_degree_desc(&capped).graph;
    assert!(graph.max_degree() <= KERNEL_L);
    let roots: Vec<u32> = (0..graph.num_vertices() as u32).collect();
    println!(
        "end-to-end graph: |V|={} |E|={} max-degree={}",
        graph.num_vertices(),
        graph.num_edges(),
        graph.max_degree()
    );

    let mut miner = PimMiner::new(PimConfig::default(), SimOptions::all());
    miner.load_graph(graph.clone())?;
    miner.verify_device_contents()?;

    // ---- 2. Fig. 9 ladder on 4-CC
    let app = application("4-CC").unwrap();
    let cfg = PimConfig::default();
    let mut ladder = Table::new(
        "optimization ladder (4-CC, Fig. 9 reproduction)",
        &["Config", "Total", "AvgCore", "Near%", "Speedup"],
    );
    let mut base = None;
    let mut pim_count = 0;
    for (name, opts) in SimOptions::ladder() {
        let r = simulate_app(&graph, &app, &roots, &opts, &cfg);
        let b = *base.get_or_insert(r.seconds);
        pim_count = r.count;
        ladder.row(vec![
            name.to_string(),
            report::s(r.seconds),
            report::s(r.avg_unit_seconds),
            report::pct(r.access.near_frac()),
            report::x(b / r.seconds),
        ]);
    }
    ladder.print();
    assert!(pim_count > 0, "workload must contain 4-cliques");

    // ---- 3a. CPU cross-check
    let t = std::time::Instant::now();
    let cpu_r = cpu::run_application(&graph, &app, &roots, CpuFlavor::AutoMineOpt);
    println!(
        "CPU check: count={} in {} — {}",
        cpu_r.count,
        report::s(t.elapsed().as_secs_f64()),
        if cpu_r.count == pim_count { "MATCHES PIM" } else { "MISMATCH!" }
    );
    assert_eq!(cpu_r.count, pim_count, "CPU and PIM disagree");

    // ---- 3b. AOT/PJRT cross-check: 3-CC via the Pallas artifact.
    if !artifacts_available() {
        println!("artifacts missing — run `make artifacts` for the PJRT cross-check");
        return Ok(());
    }
    let tri_app = application("3-CC").unwrap();
    let tri_pim = simulate_app(&graph, &tri_app, &roots, &SimOptions::all(), &cfg).count;

    let rt = Runtime::cpu()?;
    let kernel = SetOpsKernel::load(&rt, &artifacts_dir().join("setops.hlo.txt"), KERNEL_B, KERNEL_L)?;
    let mut requests = Vec::new();
    for u in 0..graph.num_vertices() as u32 {
        for &v in graph.neighbors(u) {
            if v < u {
                requests.push(SetOpRequest {
                    a: graph.neighbors(u).to_vec(),
                    b: graph.neighbors(v).to_vec(),
                    th: v,
                });
            }
        }
    }
    let t = std::time::Instant::now();
    let counts = kernel.run(&requests)?;
    let elapsed = t.elapsed().as_secs_f64();
    let aot_total: u64 = counts.iter().map(|&(i, _)| i as u64).sum();
    println!(
        "AOT/PJRT check: {} edge tiles in {} ({:.0} pairs/s) → triangles={} — {}",
        requests.len(),
        report::s(elapsed),
        requests.len() as f64 / elapsed,
        aot_total,
        if aot_total == tri_pim { "MATCHES PIM" } else { "MISMATCH!" }
    );
    assert_eq!(aot_total, tri_pim, "AOT artifact and PIM simulator disagree");
    println!("all three layers agree — end-to-end OK");
    Ok(())
}
