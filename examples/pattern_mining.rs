//! Pattern *mining* walkthrough (DESIGN.md §8): the two discovery
//! workloads on the simulated PIM machine, cross-checked against
//! independent counting paths.
//!
//!   1. one-pass 4-motif census (`PIMMotifCount`) on a power-law graph,
//!      validated against a compiled per-pattern plan;
//!   2. frequent subgraph mining (`PIMFrequentMine`) on a labeled copy of
//!      the same graph;
//!   3. the support-aggregation traffic breakdown, with and without the
//!      PIM-friendly address remap — the mining-specific cost the
//!      counting workloads never pay.
//!
//! Run: `cargo run --release --example pattern_mining`

use pimminer::coordinator::PimMiner;
use pimminer::exec::cpu::{self, CpuFlavor};
use pimminer::graph::{gen, sort_by_degree_desc};
use pimminer::mine::FsmConfig;
use pimminer::pattern::compile::{compile_with, CostModel};
use pimminer::pim::{PimConfig, SimOptions, SimResult};
use pimminer::report::{self, Table};

fn remote_agg_bytes(r: &SimResult) -> u64 {
    r.agg.intra_bytes + r.agg.inter_bytes
}

fn main() -> anyhow::Result<()> {
    let raw = gen::power_law(2_500, 12_000, 150, 5);
    let graph = sort_by_degree_desc(&raw).graph;
    println!(
        "mining graph: |V|={} |E|={}",
        graph.num_vertices(),
        graph.num_edges()
    );

    // ---- 1. PIMMotifCount + independent validation
    let mut miner = PimMiner::new(PimConfig::default(), SimOptions::all());
    miner.load_graph(graph.clone())?;
    let r = miner.motif_count(4, 1.0)?;
    let mut census_table = Table::new(
        "4-motif census (PIMMotifCount)",
        &["Motif", "Edges", "Count", "Plan check"],
    );
    let model = CostModel::for_graph(&graph);
    let roots: Vec<u32> = (0..graph.num_vertices() as u32).collect();
    for (m, &c) in r.census.motifs.iter().zip(&r.census.counts) {
        let compiled = compile_with(m, &model, true).expect("motif compiles");
        let expected = cpu::count_plan(&graph, &compiled.plan, &roots, CpuFlavor::AutoMineOpt);
        assert_eq!(c, expected, "census and compiled plan disagree on {}", m.name);
        census_table.row(vec![
            m.name.clone(),
            m.num_edges().to_string(),
            c.to_string(),
            "ok".to_string(),
        ]);
    }
    census_table.print();
    println!(
        "census: {} subgraphs, simulated {}; aggregation {} over {} updates\n",
        r.census.total(),
        report::s(r.sim.seconds),
        report::bytes(r.sim.agg.total()),
        r.sim.agg_updates
    );

    // ---- 2. PIMFrequentMine on a labeled copy
    let labeled = gen::with_random_labels(graph.clone(), 3, 17);
    let mut labeled_miner = PimMiner::new(PimConfig::default(), SimOptions::all());
    labeled_miner.load_graph(labeled)?;
    let threshold = (graph.num_vertices() / 20) as u64;
    let (fsm, fsm_sim) = labeled_miner.frequent_mine(&FsmConfig {
        min_support: threshold,
        max_size: 3,
    })?;
    let mut fsm_table = Table::new(
        &format!("frequent labeled patterns (support ≥ {threshold})"),
        &["Pattern", "Support", "Embeddings"],
    );
    for f in &fsm.frequent {
        fsm_table.row(vec![
            f.pattern.describe(),
            f.support.to_string(),
            f.embeddings.to_string(),
        ]);
    }
    fsm_table.print();
    println!(
        "FSM: {} frequent patterns, simulated {}; merge {}\n",
        fsm.frequent.len(),
        report::s(fsm_sim.seconds),
        report::bytes(fsm_sim.agg_merge_bytes)
    );

    // ---- 3. aggregation traffic: remap moves support updates near-core
    let mut agg_table = Table::new(
        "support-aggregation traffic (4-motif census)",
        &["Config", "Near%", "Intra%", "Inter%", "Remote bytes"],
    );
    let mut remote = Vec::new();
    for (name, opts) in [
        ("Baseline", SimOptions::BASELINE),
        ("Full stack", SimOptions::all()),
    ] {
        let mut m = PimMiner::new(PimConfig::default(), opts);
        m.load_graph(graph.clone())?;
        let sim = m.motif_count(4, 1.0)?.sim;
        remote.push(remote_agg_bytes(&sim));
        agg_table.row(vec![
            name.to_string(),
            report::pct(sim.agg.near_frac()),
            report::pct(sim.agg.intra_frac()),
            report::pct(sim.agg.inter_frac()),
            report::bytes(remote_agg_bytes(&sim)),
        ]);
    }
    agg_table.print();
    assert!(
        remote[1] < remote[0],
        "remap must shrink remote aggregation traffic"
    );
    println!("remap cuts remote aggregation bytes {}x", remote[0] / remote[1].max(1));
    Ok(())
}
