//! Scheduler lab — an ablation over the §4.4 stealing design space:
//! steal overhead sensitivity (the paper fixes 280 cycles = 2× remote
//! latency) and channel-first victim scanning vs the task skew, printed as
//! Exe/Avg imbalance and makespan per configuration.
//!
//! Run: `cargo run --release --example scheduler_lab`

use pimminer::exec::cpu::sampled_roots;
use pimminer::graph::{gen, sort_by_degree_desc};
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::report::{self, Table};

fn main() {
    // LiveJournal-like skew at lab scale: a few giant roots dominate.
    let graph = sort_by_degree_desc(&gen::power_law(20_000, 150_000, 4_000, 3)).graph;
    let roots = sampled_roots(graph.num_vertices(), 0.5);
    let app = application("4-CC").unwrap();
    println!(
        "lab graph: |V|={} |E|={} max-degree={} ({} roots)",
        graph.num_vertices(),
        graph.num_edges(),
        graph.max_degree(),
        roots.len()
    );

    let base_opts = SimOptions {
        filter: true,
        remap: true,
        duplication: true,
        ..SimOptions::BASELINE
    };

    // --- Part 1: stealing on/off (Table 8's comparison) ---
    let mut t = Table::new(
        "stealing on/off (4-CC)",
        &["Config", "Makespan", "AvgCore", "Exe/Avg", "Steals"],
    );
    let cfg = PimConfig::default();
    for (name, stealing) in [("no-steal", false), ("steal", true)] {
        let r = simulate_app(&graph, &app, &roots, &SimOptions { stealing, ..base_opts }, &cfg);
        t.row(vec![
            name.to_string(),
            report::s(r.seconds),
            report::s(r.avg_unit_seconds),
            format!("{:.3}", r.exe_over_avg()),
            r.steals.to_string(),
        ]);
    }
    t.print();

    // --- Part 2: steal-overhead sensitivity (the paper's 280 = 2×140) ---
    let mut t2 = Table::new(
        "steal overhead sensitivity",
        &["Overhead (cycles)", "Makespan", "Exe/Avg", "Steals"],
    );
    for overhead in [0u64, 70, 140, 280, 1_120, 8_960, 71_680] {
        let cfg = PimConfig { steal_overhead: overhead, ..PimConfig::default() };
        let r = simulate_app(
            &graph,
            &app,
            &roots,
            &SimOptions { stealing: true, ..base_opts },
            &cfg,
        );
        t2.row(vec![
            overhead.to_string(),
            report::s(r.seconds),
            format!("{:.3}", r.exe_over_avg()),
            r.steals.to_string(),
        ]);
    }
    t2.print();
    println!("higher steal overhead → fewer profitable steals → residual imbalance;\nthe paper's 280-cycle overhead sits comfortably in the flat region.");
}
