//! Set-centric compute-unit ablation — the paper's stated future work
//! (§8: "PIMMiner can be further optimized with set-centric computing
//! units like the ones in SISA, FlexMiner, DIMMining and NDMiner").
//!
//! The simulator's `scan_elems_per_cycle` models the PIM core's set-op
//! throughput; sweeping it from the baseline general-purpose core (1) to
//! an idealized 16-wide set unit quantifies how much headroom specialized
//! hardware adds *after* PIMMiner's memory optimizations — and shows the
//! workload turning memory-bound, which is why the paper argues the
//! architecture-aware optimizations come first.
//!
//! Run: `cargo run --release --example set_unit_ablation`

use pimminer::exec::cpu::sampled_roots;
use pimminer::graph::{gen, sort_by_degree_desc};
use pimminer::pattern::plan::application;
use pimminer::pim::{simulate_app, PimConfig, SimOptions};
use pimminer::report::{self, Table};

fn main() {
    let graph = sort_by_degree_desc(&gen::power_law(25_000, 260_000, 600, 11)).graph;
    let roots = sampled_roots(graph.num_vertices(), 1.0);
    println!(
        "ablation graph: |V|={} |E|={}",
        graph.num_vertices(),
        graph.num_edges()
    );

    for (cfg_name, opts) in [
        ("baseline PIM (no PIMMiner opts)", SimOptions::BASELINE),
        ("PIMMiner (all opts)", SimOptions::all()),
    ] {
        let mut t = Table::new(
            &format!("set-unit width sweep — {cfg_name} (4-CC)"),
            &["set ops/cycle", "Time", "Speedup vs 1x", "marginal gain"],
        );
        let mut first = None;
        let mut prev = None;
        for width in [1u64, 2, 4, 8, 16] {
            let cfg = PimConfig {
                scan_elems_per_cycle: width,
                ..PimConfig::default()
            };
            let app = application("4-CC").unwrap();
            let r = simulate_app(&graph, &app, &roots, &opts, &cfg);
            let base = *first.get_or_insert(r.seconds);
            let marginal = prev.map(|p: f64| p / r.seconds).unwrap_or(1.0);
            prev = Some(r.seconds);
            t.row(vec![
                format!("{width}x"),
                report::s(r.seconds),
                report::x(base / r.seconds),
                report::x(marginal),
            ]);
        }
        t.print();
    }
    println!(
        "wider set units show diminishing returns once transfers dominate —\n\
         the memory-side optimizations must come first, which is the paper's thesis."
    );
}
