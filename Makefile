# Convenience targets. `make artifacts` builds the AOT Layer-1/2 kernels
# (requires a Python with jax installed); everything else is plain cargo.

PYTHON ?= python3

.PHONY: build test bench bench-diff artifacts doc fmt verify

build:
	cargo build --release

test:
	cargo test -q

# Every [[bench]] target is a plain binary (no criterion offline);
# PIMMINER_BENCH_QUICK=1 trims iteration counts, PIMMINER_THREADS=<n>
# pins the worker count for reproducible runs on shared machines. The
# trailing invocations refresh the machine-readable perf trajectory
# seeds (BENCH_micro.json, BENCH_fusion.json, BENCH_parallel.json, and
# BENCH_faults.json at the repo root); every document carries a meta
# block (schema_version 2: threads, host cores, per-bench config —
# DESIGN.md §13) so runs from different machines/configs are
# distinguishable. The parallel bench also gates the observability
# overhead and zero-fault overhead budgets; the faults bench reports
# recovery overhead vs fault rate (DESIGN.md §15); the service bench
# reports serving throughput under concurrency and injected faults and
# refreshes BENCH_service.json (DESIGN.md §16).
bench:
	cargo bench
	cargo bench --bench perf_micro -- --json
	cargo bench --bench fusion -- --json
	cargo bench --bench parallel -- --json
	cargo bench --bench faults -- --json
	cargo bench --bench service -- --json

# Regression gate over two bench sessions (tools/bench_diff.py): fails
# when any shared timing regresses beyond the threshold (default 10%).
#   make bench-diff OLD=baseline/BENCH_micro.json NEW=BENCH_micro.json
# Extra gates ride through DIFF_FLAGS, e.g.
#   DIFF_FLAGS='--timing-threshold 5 --metric "disabled-hook ns=-25"'
bench-diff:
	$(PYTHON) tools/bench_diff.py $(OLD) $(NEW) $(DIFF_FLAGS)

# AOT-lower the Pallas/jnp set-operation kernels to HLO text under
# artifacts/ at the repo root (where runtime::artifacts_dir finds them).
artifacts:
	cd python/compile && $(PYTHON) aot.py --out-dir ../../artifacts

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

fmt:
	cargo fmt --check

# Cross-check compiled pattern plans against the brute-force reference.
verify: build
	./target/release/pimminer verify
